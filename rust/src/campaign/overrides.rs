//! Shared grid-axis override backend.
//!
//! Three frontends set [`Grid`](crate::campaign::Grid) axes by name: the
//! CLI flags (`ckptwin campaign/validate/metrics --procs … --strategies …`),
//! the scenario-file `[axes]` section (`scenario::compile`), and tests.
//! They all funnel through [`apply_override`], which is what guarantees a
//! compiled `.ckpt` file and the equivalent CLI invocation produce
//! byte-identical cell keys: there is exactly one place where an axis
//! value string becomes grid state.
//!
//! Unknown axis keys are errors (with a nearest-match suggestion), not
//! silently ignored — a typo like `--strategis` used to run the full
//! default grid without complaint.

use crate::campaign::Grid;
use crate::predictor::registry as predictors;
use crate::sim::distribution::Law;
use crate::strategy::registry as strategies;
use crate::util::split_top_level;

/// Every axis key understood by [`apply_override`], in display order.
/// CLI flag names and scenario-file `[axes]` keys are identical.
pub const AXIS_KEYS: &[&str] = &[
    "procs",
    "cp-ratios",
    "laws",
    "predictors",
    "windows",
    "strategies",
    "scale",
    "shards",
    "uniform-fp",
];

/// Levenshtein edit distance; small inputs only (axis keys, registry ids).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Closest candidate to `needle` (case-insensitive), if any is within an
/// edit distance of `max(2, needle.len() / 3)`. Ties keep the earliest
/// candidate, so deterministic for a fixed candidate order.
pub fn nearest<'a>(needle: &str, candidates: impl IntoIterator<Item = &'a str>) -> Option<&'a str> {
    let needle = needle.to_ascii_lowercase();
    let budget = 2.max(needle.len() / 3);
    let mut best: Option<(usize, &str)> = None;
    for cand in candidates {
        let d = edit_distance(&needle, &cand.to_ascii_lowercase());
        if d <= budget && best.map_or(true, |(bd, _)| d < bd) {
            best = Some((d, cand));
        }
    }
    best.map(|(_, c)| c)
}

/// Reject option keys outside `AXIS_KEYS ∪ extra_allowed`, suggesting the
/// nearest known key. `extra_allowed` carries the per-subcommand
/// non-axis options (`--out`, `--instances`, …).
pub fn check_keys<'a>(
    present: impl IntoIterator<Item = &'a str>,
    extra_allowed: &[&str],
) -> Result<(), String> {
    for key in present {
        if AXIS_KEYS.contains(&key) || extra_allowed.contains(&key) {
            continue;
        }
        let known = AXIS_KEYS.iter().chain(extra_allowed.iter()).copied();
        return Err(match nearest(key, known) {
            Some(s) => format!("unknown option '--{key}' (did you mean '--{s}'?)"),
            None => format!("unknown option '--{key}'"),
        });
    }
    Ok(())
}

fn parse_vals<T>(
    raw: &str,
    what: &str,
    parse: impl Fn(&str) -> Result<T, String>,
) -> Result<Vec<T>, String> {
    let mut out = Vec::new();
    for piece in split_top_level(raw) {
        let piece = piece.trim();
        if piece.is_empty() {
            continue;
        }
        out.push(parse(piece).map_err(|e| format!("bad {what} '{piece}': {e}"))?);
    }
    if out.is_empty() {
        return Err(format!("empty {what} list"));
    }
    Ok(out)
}

/// `parse_strategy_list` with a nearest-registry-id suggestion appended
/// when the failing token's base name is a near-miss of a known
/// strategy name or alias.
fn parse_strategies(raw: &str) -> Result<Vec<crate::strategy::StrategyId>, String> {
    strategies::parse_strategy_list(raw).map_err(|e| {
        let ids: Vec<&'static str> = strategies::catalog()
            .flat_map(|d| std::iter::once(d.name).chain(d.aliases.iter().copied()))
            .collect();
        suggest_registry_id(raw, &ids)
            .map(|s| format!("{e} (did you mean '{s}'?)"))
            .unwrap_or(e)
    })
}

fn parse_predictors(raw: &str) -> Result<Vec<crate::predictor::PredictorId>, String> {
    predictors::parse_predictor_list(raw).map_err(|e| {
        let ids: Vec<&'static str> = predictors::catalog()
            .flat_map(|d| std::iter::once(d.name).chain(d.aliases.iter().copied()))
            .collect();
        suggest_registry_id(raw, &ids)
            .map(|s| format!("{e} (did you mean '{s}'?)"))
            .unwrap_or(e)
    })
}

/// Find the first token in `raw` whose base name is not a known id and
/// return the nearest candidate, if any.
fn suggest_registry_id<'a>(raw: &str, candidates: &[&'a str]) -> Option<&'a str> {
    for tok in split_top_level(raw) {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let base = tok.split('(').next().unwrap_or(tok).trim();
        if !candidates.iter().any(|c| c.eq_ignore_ascii_case(base)) {
            return nearest(base, candidates.iter().copied());
        }
    }
    None
}

/// Set one grid axis from its string value. Unknown `key` is an error
/// (with the nearest axis-key suggestion); so are out-of-range values
/// (`procs`/`shards` must be ≥ 1, `scale` finite and > 0) and unknown
/// registry ids inside `strategies`/`predictors` lists.
pub fn apply_override(grid: &mut Grid, key: &str, value: &str) -> Result<(), String> {
    match key {
        "procs" => {
            grid.procs = parse_vals(value, "processor count", |s| {
                s.parse::<u64>().map_err(|e| e.to_string()).and_then(|n| {
                    if n == 0 {
                        Err("must be >= 1".into())
                    } else {
                        Ok(n)
                    }
                })
            })?;
        }
        "cp-ratios" => {
            grid.cp_ratios =
                parse_vals(value, "Cp ratio", |s| s.parse::<f64>().map_err(|e| e.to_string()))?;
        }
        "laws" => {
            grid.fault_laws = parse_vals(value, "fault law", |s| {
                Law::parse(s).ok_or_else(|| {
                    "expected exponential|weibullK|lognormalS|uniform".to_string()
                })
            })?;
        }
        "predictors" => grid.predictors = parse_predictors(value)?,
        "windows" => {
            grid.windows = parse_vals(value, "window length", |s| {
                s.parse::<f64>().map_err(|e| e.to_string())
            })?;
        }
        "strategies" => grid.strategies = parse_strategies(value)?,
        "scale" => {
            let scale: f64 = value
                .trim()
                .parse()
                .map_err(|_| format!("bad scale '{value}'"))?;
            if !scale.is_finite() || scale <= 0.0 {
                return Err(format!("scale must be finite and > 0, got '{value}'"));
            }
            grid.scale = scale;
        }
        "shards" => {
            grid.platform_shards = parse_vals(value, "shard count", |s| {
                s.parse::<u32>().map_err(|e| e.to_string()).and_then(|n| {
                    if n == 0 {
                        Err("must be >= 1".into())
                    } else {
                        Ok(n)
                    }
                })
            })?;
        }
        "uniform-fp" => {
            grid.uniform_false_preds = match value.trim() {
                "" | "true" => true,
                "false" => false,
                other => return Err(format!("bad uniform-fp value '{other}' (true|false)")),
            };
        }
        other => {
            return Err(match nearest(other, AXIS_KEYS.iter().copied()) {
                Some(s) => format!("unknown grid axis '{other}' (did you mean '{s}'?)"),
                None => format!("unknown grid axis '{other}'"),
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("", ""), 0);
        assert_eq!(edit_distance("abc", "abc"), 0);
        assert_eq!(edit_distance("abc", "abd"), 1);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
        assert_eq!(edit_distance("", "abc"), 3);
    }

    #[test]
    fn nearest_suggests_within_budget() {
        assert_eq!(nearest("procz", AXIS_KEYS.iter().copied()), Some("procs"));
        assert_eq!(nearest("strategis", AXIS_KEYS.iter().copied()), Some("strategies"));
        assert_eq!(nearest("zzzzzz", AXIS_KEYS.iter().copied()), None);
    }

    #[test]
    fn unknown_axis_errors_with_suggestion() {
        let mut g = Grid::smoke();
        let err = apply_override(&mut g, "strategis", "Daly").unwrap_err();
        assert!(err.contains("unknown grid axis 'strategis'"), "{err}");
        assert!(err.contains("did you mean 'strategies'"), "{err}");
    }

    #[test]
    fn bad_registry_id_suggests_nearest() {
        let mut g = Grid::smoke();
        let err = apply_override(&mut g, "strategies", "dailly").unwrap_err();
        assert!(err.contains("unknown strategy"), "{err}");
        assert!(err.to_ascii_lowercase().contains("did you mean 'daly'"), "{err}");
        let err = apply_override(&mut g, "predictors", "mixedwim").unwrap_err();
        assert!(err.contains("did you mean 'mixedwin'"), "{err}");
    }

    #[test]
    fn out_of_range_values_rejected() {
        let mut g = Grid::smoke();
        assert!(apply_override(&mut g, "procs", "0").is_err());
        assert!(apply_override(&mut g, "shards", "0").is_err());
        assert!(apply_override(&mut g, "scale", "-1").is_err());
        assert!(apply_override(&mut g, "scale", "nan").is_err());
        assert!(apply_override(&mut g, "laws", "weibull").is_err());
    }

    #[test]
    fn check_keys_allows_axes_and_extras() {
        assert!(check_keys(["procs", "out"], &["out"]).is_ok());
        let err = check_keys(["instancs"], &["instances"]).unwrap_err();
        assert!(err.contains("did you mean '--instances'"), "{err}");
    }

    #[test]
    fn uniform_fp_round_trips() {
        let mut g = Grid::smoke();
        assert!(!g.uniform_false_preds);
        apply_override(&mut g, "uniform-fp", "true").unwrap();
        assert!(g.uniform_false_preds);
        apply_override(&mut g, "uniform-fp", "false").unwrap();
        assert!(!g.uniform_false_preds);
        assert!(apply_override(&mut g, "uniform-fp", "maybe").is_err());
    }
}
