//! Resumable on-disk result store: one JSON line per completed cell,
//! keyed by the stable scenario hash.
//!
//! Cells are appended (and flushed) as they complete, so an interrupted
//! campaign loses at most the cells in flight; `campaign resume` reopens
//! the store, reads the hashes already present, and recomputes only the
//! missing cells — the sweep runner itself checkpoints, mirroring the
//! paper's subject.  A torn final line (the process died mid-write) is
//! detected and ignored on load.
//!
//! Hashes are serialized as 16-digit hex strings, not JSON numbers: JSON
//! numbers round-trip through f64 and would corrupt 64-bit keys.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::jsonio::{self, JsonlAppender, RecordCheck, Value};
use crate::resilience::failpoint::{self, Site};
use crate::resilience::retry::Backoff;

/// One persisted cell result (one JSONL line).
#[derive(Clone, Debug, PartialEq)]
pub struct CellRecord {
    /// Stable scenario hash ([`crate::campaign::grid::Cell::hash`]).
    pub hash: u64,
    /// Canonical cell key (provenance; greppable).
    pub key: String,
    pub instances: u64,
    pub waste_mean: f64,
    pub waste_var: f64,
    pub waste_ci95: f64,
    pub waste_min: f64,
    pub waste_max: f64,
    pub makespan_mean: f64,
    /// Regular period the strategy used (s).
    pub tr: f64,
}

impl CellRecord {
    fn to_json(&self) -> String {
        let mut obj = BTreeMap::new();
        obj.insert("hash".into(), Value::Str(format!("{:016x}", self.hash)));
        obj.insert("key".into(), Value::Str(self.key.clone()));
        obj.insert("instances".into(), Value::Num(self.instances as f64));
        obj.insert("waste_mean".into(), Value::Num(self.waste_mean));
        obj.insert("waste_var".into(), Value::Num(self.waste_var));
        obj.insert("waste_ci95".into(), Value::Num(self.waste_ci95));
        obj.insert("waste_min".into(), Value::Num(self.waste_min));
        obj.insert("waste_max".into(), Value::Num(self.waste_max));
        obj.insert("makespan_mean".into(), Value::Num(self.makespan_mean));
        obj.insert("tr".into(), Value::Num(self.tr));
        // Seal with a per-record CRC so interior corruption (not just a
        // torn tail) is detected and quarantined on reload.
        jsonio::seal_record(obj)
    }

    fn from_json(line: &str) -> Option<CellRecord> {
        CellRecord::from_value(&jsonio::parse(line).ok()?)
    }

    fn from_value(v: &Value) -> Option<CellRecord> {
        let num = |k: &str| v.get(k).and_then(Value::as_f64);
        Some(CellRecord {
            hash: u64::from_str_radix(v.get("hash")?.as_str()?, 16).ok()?,
            key: v.get("key")?.as_str()?.to_string(),
            instances: num("instances")? as u64,
            waste_mean: num("waste_mean")?,
            waste_var: num("waste_var")?,
            waste_ci95: num("waste_ci95")?,
            waste_min: num("waste_min")?,
            waste_max: num("waste_max")?,
            makespan_mean: num("makespan_mean")?,
            tr: num("tr")?,
        })
    }
}

/// Append-only JSONL store with an in-memory index by scenario hash.
pub struct Store {
    path: PathBuf,
    file: JsonlAppender,
    records: BTreeMap<u64, CellRecord>,
    /// Unparseable lines skipped on open (a torn tail from an interrupt).
    pub skipped_lines: usize,
    /// Lines that parsed but failed their CRC seal (interior corruption).
    /// The damaged cells are simply absent from the index, so a resume
    /// recomputes them; callers surface the count.
    pub quarantined_lines: usize,
}

impl Store {
    /// Open for resuming: parse existing records (creating the file if
    /// missing) and append new ones after them.
    pub fn open(path: impl AsRef<Path>) -> Result<Store> {
        Store::open_inner(path.as_ref(), false)
    }

    /// Open for a fresh run.  Refuses to clobber an existing *non-empty*
    /// store — a stray `create` used to silently destroy campaign
    /// results; pass `--force` (→ [`Store::create_force`]) or use
    /// `campaign resume` instead.
    pub fn create(path: impl AsRef<Path>) -> Result<Store> {
        let path = path.as_ref();
        if let Ok(meta) = std::fs::metadata(path) {
            if meta.len() > 0 {
                bail!(
                    "refusing to overwrite non-empty store {} (use --force, \
                     or resume to keep existing results)",
                    path.display()
                );
            }
        }
        Store::open_inner(path, true)
    }

    /// Open for a fresh run, truncating any existing store (`--force`).
    pub fn create_force(path: impl AsRef<Path>) -> Result<Store> {
        Store::open_inner(path.as_ref(), true)
    }

    fn open_inner(path: &Path, truncate: bool) -> Result<Store> {
        // Replay existing lines last-wins; the appender repairs a torn
        // tail and counts unparseable lines (see `jsonio::JsonlAppender`).
        // Lines whose CRC seal fails are quarantined: counted, kept out
        // of the index, but not treated as torn (they parsed fine).
        let mut records = BTreeMap::new();
        let mut quarantined_lines = 0usize;
        let file = JsonlAppender::open(path, truncate, |line| {
            let Ok(v) = jsonio::parse(line) else { return false };
            if jsonio::check_record(&v) == RecordCheck::Corrupt {
                quarantined_lines += 1;
                return true;
            }
            match CellRecord::from_value(&v) {
                Some(rec) => {
                    records.insert(rec.hash, rec);
                    true
                }
                None => false,
            }
        })?;
        let skipped_lines = file.skipped_lines;
        Ok(Store {
            path: path.to_path_buf(),
            file,
            records,
            skipped_lines,
            quarantined_lines,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn contains(&self, hash: u64) -> bool {
        self.records.contains_key(&hash)
    }

    pub fn get(&self, hash: u64) -> Option<&CellRecord> {
        self.records.get(&hash)
    }

    /// All records, ordered by hash.
    pub fn records(&self) -> impl Iterator<Item = &CellRecord> {
        self.records.values()
    }

    /// Append one completed cell and flush it to disk immediately.  A
    /// record whose hash is already present supersedes the earlier line
    /// (last-wins, both in memory and on reload) — resume uses this to
    /// upgrade cells recomputed with a higher instance count.
    ///
    /// Transient IO faults (fail point `store.append`) are absorbed by a
    /// bounded-exponential-backoff retry with deterministic jitter; any
    /// other failure surfaces after the attempts are exhausted.
    pub fn append(&mut self, rec: &CellRecord) -> Result<()> {
        let line = rec.to_json();
        let file = &mut self.file;
        Backoff::default().run(|_attempt| {
            if let Some(inj) = failpoint::check(Site::StoreAppend) {
                inj.trigger()?;
            }
            file.append_line(&line)
        })?;
        self.records.insert(rec.hash, rec.clone());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "ckptwin-store-{tag}-{}.jsonl",
            std::process::id()
        ))
    }

    fn rec(hash: u64) -> CellRecord {
        CellRecord {
            hash,
            key: format!("cell-{hash}"),
            instances: 10,
            waste_mean: 0.125,
            waste_var: 1e-4,
            waste_ci95: 0.006,
            waste_min: 0.1,
            waste_max: 0.15,
            makespan_mean: 5.5e6,
            tr: 4321.0,
        }
    }

    #[test]
    fn roundtrip_and_resume() {
        let path = tmp("rt");
        let _ = std::fs::remove_file(&path);
        {
            let mut s = Store::create(&path).unwrap();
            s.append(&rec(1)).unwrap();
            s.append(&rec(u64::MAX - 3)).unwrap();
            assert_eq!(s.len(), 2);
        }
        let s = Store::open(&path).unwrap();
        assert_eq!(s.len(), 2);
        assert!(s.contains(1));
        assert!(s.contains(u64::MAX - 3)); // 64-bit keys survive JSON
        assert_eq!(s.get(1).unwrap(), &rec(1));
        assert_eq!(s.skipped_lines, 0);
    }

    #[test]
    fn create_refuses_nonempty_force_truncates() {
        let path = tmp("trunc");
        let _ = std::fs::remove_file(&path);
        {
            let mut s = Store::create(&path).unwrap();
            s.append(&rec(7)).unwrap();
        }
        {
            let mut s = Store::open(&path).unwrap();
            assert_eq!(s.len(), 1);
            s.append(&rec(8)).unwrap();
        }
        // A stray create must not clobber the two results on disk.
        let err = Store::create(&path).unwrap_err().to_string();
        assert!(err.contains("refusing to overwrite"), "{err}");
        {
            let s = Store::open(&path).unwrap();
            assert_eq!(s.len(), 2);
        }
        // --force truncates explicitly.
        let s = Store::create_force(&path).unwrap();
        assert_eq!(s.len(), 0);
        drop(s);
        // create on an existing but empty store is fine.
        let s = Store::create(&path).unwrap();
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn interior_corruption_is_quarantined() {
        let path = tmp("quarantine");
        let _ = std::fs::remove_file(&path);
        {
            let mut s = Store::create(&path).unwrap();
            for h in [1u64, 2, 3] {
                s.append(&rec(h)).unwrap();
            }
        }
        // Corrupt a *middle* record's payload, keeping it valid JSON: the
        // line still parses, so only the CRC seal can catch it.
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        let damaged = lines[1].replace("\"instances\":10", "\"instances\":99");
        let text = format!("{}\n{}\n{}\n", lines[0], damaged, lines[2]);
        std::fs::write(&path, text).unwrap();
        let s = Store::open(&path).unwrap();
        assert_eq!(s.quarantined_lines, 1);
        assert_eq!(s.skipped_lines, 0);
        // The damaged cell is absent (a resume would recompute it); its
        // neighbours are intact.
        assert_eq!(s.len(), 2);
        assert!(s.contains(1) && !s.contains(2) && s.contains(3));
    }

    #[test]
    fn legacy_unsealed_records_still_load() {
        let path = tmp("legacy");
        let _ = std::fs::remove_file(&path);
        // A pre-seal store: records without a crc field.
        let mut legacy = String::new();
        legacy.push_str(
            "{\"hash\":\"0000000000000001\",\"instances\":10,\"key\":\"cell-1\",\
             \"makespan_mean\":5500000,\"tr\":4321,\"waste_ci95\":0.006,\
             \"waste_max\":0.15,\"waste_mean\":0.125,\"waste_min\":0.1,\
             \"waste_var\":0.0001}\n",
        );
        std::fs::write(&path, legacy).unwrap();
        let s = Store::open(&path).unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(s.quarantined_lines, 0);
        assert_eq!(s.get(1).unwrap(), &rec(1));
    }

    #[test]
    fn torn_tail_is_skipped() {
        let path = tmp("torn");
        let _ = std::fs::remove_file(&path);
        {
            let mut s = Store::create(&path).unwrap();
            s.append(&rec(11)).unwrap();
            s.append(&rec(12)).unwrap();
        }
        // Simulate an interrupt mid-write: append half a JSON line.
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"hash\":\"00000000000");
        std::fs::write(&path, text).unwrap();
        let mut s = Store::open(&path).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.skipped_lines, 1);
        // And the store stays appendable after the torn line.
        s.append(&rec(13)).unwrap();
        drop(s);
        let s = Store::open(&path).unwrap();
        assert!(s.contains(13));
    }

    #[test]
    fn reappend_supersedes_last_wins() {
        let path = tmp("supersede");
        let _ = std::fs::remove_file(&path);
        {
            let mut s = Store::create(&path).unwrap();
            s.append(&rec(5)).unwrap();
            let mut upgraded = rec(5);
            upgraded.instances = 100;
            s.append(&upgraded).unwrap();
            assert_eq!(s.len(), 1);
            assert_eq!(s.get(5).unwrap().instances, 100);
        }
        // Last-wins survives reload (two physical lines, one record).
        let s = Store::open(&path).unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(s.get(5).unwrap().instances, 100);
    }
}
