//! Deterministic, seeded fail-point registry.
//!
//! A *fail point* is a named site in the production code path where a
//! fault can be injected at runtime: an IO error, a torn partial write, a
//! worker panic, or a hard process kill.  Sites are enumerated in
//! [`Site`]; the decision of whether hit `n` of a site fires is a pure
//! function of the armed [`SiteConfig`] (see [`SiteConfig::fires`]), so
//! chaos runs are bit-reproducible given the same plan.
//!
//! Zero-cost when disabled: [`check`] is a single relaxed atomic load on
//! the fast path (the same compile-away discipline as
//! `obs::NoopRecorder`); all bookkeeping lives behind a `#[cold]` branch
//! that only runs while a plan is armed.
//!
//! Arming is process-global and serialized by a mutex so concurrent tests
//! cannot observe each other's plans; hold the returned [`ArmGuard`] for
//! the injection's lifetime.  The CLI arms via `--inject
//! "site:p=0.01,seed=42"` (see `Plan::parse` for the grammar).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

use anyhow::{anyhow, bail, Result};

use crate::sim::rng::Rng;

/// Marker substring present in every injected *transient* error message.
/// The vendored `anyhow` is string-backed (no downcasting), so transient
/// classification — the only retryable class — matches on this text.
pub const TRANSIENT_MARK: &str = "injected transient fault";

/// Marker substring present in every injected *crash* error message.
pub const CRASH_MARK: &str = "injected crash";

/// Named injection sites threaded through the production layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Site {
    /// `campaign::Store::append` / `validate::ConformanceStore::append`
    /// attempt body (before the line reaches the appender).
    StoreAppend,
    /// `jsonio::JsonlAppender::append_line` — supports `mode=torn`
    /// (a deterministic partial-line write followed by a crash error).
    JsonlTail,
    /// `campaign::scheduler` worker body, before each unit runs.
    SchedWorker,
    /// `campaign::pool::TracePool::replay` miss path, before the insert.
    PoolInsert,
    /// Top of each `coordinator::run` pass (one `'outer` iteration).
    CoordPass,
    /// `resilience::snapshot::SnapshotStore::save` body.
    SnapshotWrite,
}

impl Site {
    pub const ALL: [Site; 6] = [
        Site::StoreAppend,
        Site::JsonlTail,
        Site::SchedWorker,
        Site::PoolInsert,
        Site::CoordPass,
        Site::SnapshotWrite,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Site::StoreAppend => "store.append",
            Site::JsonlTail => "jsonl.tail",
            Site::SchedWorker => "sched.worker",
            Site::PoolInsert => "pool.insert",
            Site::CoordPass => "coord.pass",
            Site::SnapshotWrite => "snapshot.write",
        }
    }

    pub fn parse(name: &str) -> Option<Site> {
        Site::ALL.iter().copied().find(|s| s.name() == name)
    }

    pub fn index(self) -> usize {
        match self {
            Site::StoreAppend => 0,
            Site::JsonlTail => 1,
            Site::SchedWorker => 2,
            Site::PoolInsert => 3,
            Site::CoordPass => 4,
            Site::SnapshotWrite => 5,
        }
    }
}

/// What happens when a site fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Retryable IO error (clears on the next attempt unless it fires
    /// again) — exercises the bounded-backoff retry path.
    Transient,
    /// Torn write: at `jsonl.tail` a deterministic partial line is
    /// flushed before the crash error; elsewhere it degrades to a plain
    /// crash error.
    Torn,
    /// Worker panic — exercises `catch_unwind` containment.
    Panic,
    /// Hard process kill (`abort`) — exercises true crash–resume.
    Kill,
}

impl Mode {
    pub fn name(self) -> &'static str {
        match self {
            Mode::Transient => "transient",
            Mode::Torn => "torn",
            Mode::Panic => "panic",
            Mode::Kill => "kill",
        }
    }

    pub fn parse(name: &str) -> Option<Mode> {
        [Mode::Transient, Mode::Torn, Mode::Panic, Mode::Kill]
            .into_iter()
            .find(|m| m.name() == name)
    }
}

/// Armed behaviour of one site.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SiteConfig {
    pub site: Site,
    pub mode: Mode,
    /// Per-hit firing probability (ignored when `nth` is set).
    pub p: f64,
    /// Fire exactly on the nth hit (1-based), once.
    pub nth: Option<u64>,
    /// Seed for the per-hit Bernoulli draw.
    pub seed: u64,
}

impl SiteConfig {
    /// Pure firing decision for 1-based hit counter `hit`: a function of
    /// `(site, seed, hit)` only, so replaying a plan replays its faults.
    pub fn fires(&self, hit: u64) -> bool {
        if let Some(n) = self.nth {
            return hit == n;
        }
        if self.p <= 0.0 {
            return false;
        }
        Rng::stream(self.seed ^ (0x51_7e << 8 | self.site.index() as u64), hit)
            .f64()
            < self.p
    }
}

/// A full injection plan: at most one config per site.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Plan {
    pub sites: Vec<SiteConfig>,
}

impl Plan {
    /// Parse the CLI grammar: `site:key=val,key=val[;site:...]` with keys
    /// `p` (probability), `nth` (1-based hit), `seed`, `mode`
    /// (`transient|torn|panic|kill`, default `kill`).  Examples:
    /// `store.append:p=0.01,seed=42,mode=transient` or
    /// `jsonl.tail:nth=3,mode=torn`.
    pub fn parse(spec: &str) -> Result<Plan> {
        let mut sites = Vec::new();
        for part in spec.split(';').filter(|s| !s.trim().is_empty()) {
            let part = part.trim();
            let (name, opts) = part
                .split_once(':')
                .ok_or_else(|| anyhow!("inject spec `{part}`: expected site:opts"))?;
            let site = Site::parse(name.trim()).ok_or_else(|| {
                anyhow!(
                    "inject spec `{part}`: unknown site `{}` (valid: {})",
                    name.trim(),
                    Site::ALL.map(Site::name).join(", ")
                )
            })?;
            let mut cfg = SiteConfig { site, mode: Mode::Kill, p: 0.0, nth: None, seed: 0 };
            for kv in opts.split(',').filter(|s| !s.trim().is_empty()) {
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| anyhow!("inject spec `{part}`: bad option `{kv}`"))?;
                let (k, v) = (k.trim(), v.trim());
                match k {
                    "p" => {
                        cfg.p = v
                            .parse()
                            .map_err(|_| anyhow!("inject spec `{part}`: bad p `{v}`"))?
                    }
                    "nth" => {
                        cfg.nth = Some(v.parse().map_err(|_| {
                            anyhow!("inject spec `{part}`: bad nth `{v}`")
                        })?)
                    }
                    "seed" => {
                        cfg.seed = v.parse().map_err(|_| {
                            anyhow!("inject spec `{part}`: bad seed `{v}`")
                        })?
                    }
                    "mode" => {
                        cfg.mode = Mode::parse(v).ok_or_else(|| {
                            anyhow!("inject spec `{part}`: bad mode `{v}`")
                        })?
                    }
                    _ => bail!("inject spec `{part}`: unknown key `{k}`"),
                }
            }
            if cfg.p <= 0.0 && cfg.nth.is_none() {
                bail!("inject spec `{part}`: needs p= or nth=");
            }
            sites.push(cfg);
        }
        Ok(Plan { sites })
    }
}

/// A fired injection, produced by [`check`].
#[derive(Clone, Copy, Debug)]
pub struct Injection {
    pub site: Site,
    pub mode: Mode,
    /// 1-based hit count at which this injection fired.
    pub hit: u64,
}

impl Injection {
    /// The error this injection maps to (for `Transient`/`Torn` modes).
    pub fn to_error(&self) -> anyhow::Error {
        match self.mode {
            Mode::Transient => anyhow!(
                "{} at {} (hit {})",
                TRANSIENT_MARK,
                self.site.name(),
                self.hit
            ),
            _ => anyhow!("{} at {} (hit {})", CRASH_MARK, self.site.name(), self.hit),
        }
    }

    /// Act out the injection at a `Result`-returning site: `Transient` /
    /// `Torn` become errors, `Panic` panics, `Kill` aborts the process.
    pub fn trigger(&self) -> Result<()> {
        match self.mode {
            Mode::Transient | Mode::Torn => Err(self.to_error()),
            Mode::Panic => panic!(
                "injected panic at {} (hit {})",
                self.site.name(),
                self.hit
            ),
            Mode::Kill => kill_now(self),
        }
    }
}

/// Abort the process, announcing the injection on stderr first (the chaos
/// harness greps the message in the child's output).
pub fn kill_now(inj: &Injection) -> ! {
    eprintln!("ckptwin: injected kill at {} (hit {})", inj.site.name(), inj.hit);
    std::process::abort();
}

static ARMED: AtomicBool = AtomicBool::new(false);

static HITS: [AtomicU64; 6] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];

static FIRED: [AtomicU64; 6] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];

fn plan_slot() -> &'static Mutex<Plan> {
    static SLOT: OnceLock<Mutex<Plan>> = OnceLock::new();
    SLOT.get_or_init(|| Mutex::new(Plan::default()))
}

fn arm_mutex() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

/// Keeps the plan armed; disarms (and clears counters' ownership) on drop.
/// Also holds the global arm mutex, serializing concurrent armers.
pub struct ArmGuard {
    _lock: MutexGuard<'static, ()>,
}

impl Drop for ArmGuard {
    fn drop(&mut self) {
        ARMED.store(false, Ordering::SeqCst);
        *plan_slot().lock().unwrap_or_else(|e| e.into_inner()) = Plan::default();
    }
}

/// Arm `plan` process-wide, resetting all hit/fired counters.  Injection
/// stays live until the returned guard drops.
pub fn arm(plan: Plan) -> ArmGuard {
    // An injected panic can poison the mutex of a previous armer; recover.
    let lock = arm_mutex().lock().unwrap_or_else(|e| e.into_inner());
    for i in 0..Site::ALL.len() {
        HITS[i].store(0, Ordering::SeqCst);
        FIRED[i].store(0, Ordering::SeqCst);
    }
    *plan_slot().lock().unwrap_or_else(|e| e.into_inner()) = plan;
    ARMED.store(true, Ordering::SeqCst);
    ArmGuard { _lock: lock }
}

/// Fast-path probe called from production sites.  One relaxed load when
/// nothing is armed; hit accounting and the firing decision live in the
/// cold half.
#[inline]
pub fn check(site: Site) -> Option<Injection> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    check_armed(site)
}

#[cold]
fn check_armed(site: Site) -> Option<Injection> {
    let cfg = {
        let plan = plan_slot().lock().unwrap_or_else(|e| e.into_inner());
        plan.sites.iter().copied().find(|c| c.site == site)?
    };
    // Hits only count while the site is in the plan, so `nth=` schedules
    // are stable regardless of unrelated traffic before arming.
    let hit = HITS[site.index()].fetch_add(1, Ordering::SeqCst) + 1;
    if !cfg.fires(hit) {
        return None;
    }
    FIRED[site.index()].fetch_add(1, Ordering::SeqCst);
    Some(Injection { site, mode: cfg.mode, hit })
}

/// Hits recorded for `site` since the last [`arm`].
pub fn hits(site: Site) -> u64 {
    HITS[site.index()].load(Ordering::SeqCst)
}

/// Injections fired for `site` since the last [`arm`].
pub fn fired(site: Site) -> u64 {
    FIRED[site.index()].load(Ordering::SeqCst)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Unit tests here stick to the pure API (parse / fires) — arming is
    // process-global, and lib tests run multithreaded.  End-to-end armed
    // behaviour lives in `tests/resilience.rs`, which owns its process.

    #[test]
    fn site_names_roundtrip() {
        for s in Site::ALL {
            assert_eq!(Site::parse(s.name()), Some(s));
            assert_eq!(Site::ALL[s.index()], s);
        }
        assert_eq!(Site::parse("nope"), None);
    }

    #[test]
    fn plan_parse_grammar() {
        let plan =
            Plan::parse("store.append:p=0.25,seed=42,mode=transient;jsonl.tail:nth=3,mode=torn")
                .unwrap();
        assert_eq!(plan.sites.len(), 2);
        assert_eq!(plan.sites[0].site, Site::StoreAppend);
        assert_eq!(plan.sites[0].mode, Mode::Transient);
        assert!((plan.sites[0].p - 0.25).abs() < 1e-12);
        assert_eq!(plan.sites[0].seed, 42);
        assert_eq!(plan.sites[1].site, Site::JsonlTail);
        assert_eq!(plan.sites[1].nth, Some(3));
        assert_eq!(plan.sites[1].mode, Mode::Torn);

        assert!(Plan::parse("bogus.site:p=0.5").is_err());
        assert!(Plan::parse("store.append:p=zero").is_err());
        assert!(Plan::parse("store.append:frobnicate=1,p=0.5").is_err());
        // A site with neither p nor nth would never fire — reject it.
        assert!(Plan::parse("store.append:seed=9").is_err());
        // Default mode is kill.
        assert_eq!(Plan::parse("coord.pass:nth=1").unwrap().sites[0].mode, Mode::Kill);
        // Empty spec is an empty (valid) plan.
        assert!(Plan::parse("").unwrap().sites.is_empty());
    }

    #[test]
    fn fires_is_pure_and_deterministic() {
        let cfg = SiteConfig {
            site: Site::StoreAppend,
            mode: Mode::Transient,
            p: 0.3,
            nth: None,
            seed: 7,
        };
        let a: Vec<bool> = (1..=200).map(|h| cfg.fires(h)).collect();
        let b: Vec<bool> = (1..=200).map(|h| cfg.fires(h)).collect();
        assert_eq!(a, b);
        let n = a.iter().filter(|&&x| x).count();
        // ~Binomial(200, 0.3): far away from 0 and 200.
        assert!(n > 20 && n < 120, "{n}");
        // A different seed gives a different schedule.
        let other = SiteConfig { seed: 8, ..cfg };
        assert_ne!(a, (1..=200).map(|h| other.fires(h)).collect::<Vec<_>>());
    }

    #[test]
    fn nth_schedule_fires_exactly_once() {
        let cfg = SiteConfig {
            site: Site::CoordPass,
            mode: Mode::Kill,
            p: 0.0,
            nth: Some(4),
            seed: 0,
        };
        let fired: Vec<u64> = (1..=10).filter(|&h| cfg.fires(h)).collect();
        assert_eq!(fired, vec![4]);
    }

    #[test]
    fn injected_errors_carry_classification_marks() {
        let t = Injection { site: Site::StoreAppend, mode: Mode::Transient, hit: 2 };
        assert!(t.to_error().to_string().contains(TRANSIENT_MARK));
        let c = Injection { site: Site::JsonlTail, mode: Mode::Torn, hit: 5 };
        let msg = c.to_error().to_string();
        assert!(msg.contains(CRASH_MARK) && msg.contains("jsonl.tail"), "{msg}");
    }
}
