//! Crash–resume equivalence harness: the gate behind `ckptwin chaos`.
//!
//! Each cycle produces a **golden** artifact with no faults armed, then
//! reproduces it under injected crashes — killed at a randomized point,
//! resumed, killed again — and requires the survivor to match the golden
//! run exactly.  Three targets rotate per cycle:
//!
//! * **campaign store** — a reference JSONL store vs one written under
//!   torn partial-line writes (`jsonl.tail:mode=torn`) and transient IO
//!   faults (`store.append:mode=transient`), crashed and reopened until
//!   complete, then corrupted interiorly and re-opened again.  Must match
//!   record for record.
//! * **conformance store** — same contract for the validation sweep's
//!   verdict store.
//! * **coordinator** — a golden [`crate::coordinator::Report`] vs a run
//!   repeatedly crashed at the `coord.pass` fail point and resumed from
//!   the coordinator's own self-snapshot.  Must match fingerprint for
//!   fingerprint ([`crate::coordinator::Report::fingerprint`]).
//!
//! Every cycle's randomization (kill schedules, record counts, corruption
//! positions) derives from the harness seed, so a failing run is replayed
//! with `--seed`.  Counters are exported through
//! [`crate::obs::MetricsRegistry`] into `CHAOS.json`
//! (schema [`SCHEMA`]); divergences fail the run.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::campaign::grid::fnv1a64;
use crate::campaign::store::{CellRecord, Store};
use crate::config::{FaultModel, Platform, PredictorSpec, Scenario};
use crate::coordinator::{self, CoordinatorConfig, SelfCkptOptions};
use crate::coordinator::workload::SyntheticWorkload;
use crate::jsonio::{self, Value};
use crate::obs::report::registry_json;
use crate::obs::MetricsRegistry;
use crate::resilience::failpoint::{self, Plan};
use crate::resilience::retry;
use crate::resilience::snapshot::SnapshotStore;
use crate::sim::distribution::Law;
use crate::sim::rng::Rng;
use crate::strategy::{Policy, PolicyKind};
use crate::validate::store::{ConformanceRecord, ConformanceStore};

/// `CHAOS.json` schema tag; bump on breaking layout changes.
pub const SCHEMA: &str = "ckptwin-chaos/1";

/// Crash/resume attempts per cycle before the harness runs the final
/// attempt unarmed (which must then complete).
const MAX_ATTEMPTS: usize = 10;

#[derive(Clone, Debug)]
pub struct ChaosOptions {
    /// Randomized kill/resume cycles (rotating over the three targets).
    pub cycles: u64,
    /// Harness seed: every kill schedule and corruption derives from it.
    pub seed: u64,
    /// Scratch directory (created fresh; removed only by the caller).
    pub dir: PathBuf,
}

/// Aggregated outcome of a chaos run.  `divergences` empty ⇔ the gate
/// passes.
#[derive(Clone, Debug, Default)]
pub struct ChaosReport {
    pub cycles_run: u64,
    pub crashes_injected: u64,
    pub resumes: u64,
    pub torn_tails_repaired: u64,
    pub records_quarantined: u64,
    pub transient_retries: u64,
    pub divergences: Vec<String>,
}

impl ChaosReport {
    pub fn ok(&self) -> bool {
        self.divergences.is_empty()
    }

    /// Export the counters through the shared metrics registry.
    pub fn metrics(&self) -> MetricsRegistry {
        let mut m = MetricsRegistry::new();
        m.add("chaos.cycles", self.cycles_run);
        m.add("chaos.crashes_injected", self.crashes_injected);
        m.add("chaos.resumes", self.resumes);
        m.add("chaos.torn_tails_repaired", self.torn_tails_repaired);
        m.add("chaos.records_quarantined", self.records_quarantined);
        m.add("chaos.transient_retries", self.transient_retries);
        m.add("chaos.divergences", self.divergences.len() as u64);
        m
    }
}

fn is_injected(e: &anyhow::Error) -> bool {
    let s = e.to_string();
    s.contains(failpoint::TRANSIENT_MARK) || s.contains(failpoint::CRASH_MARK)
}

/// Run the full harness.  Divergences are *reported*, not returned as
/// `Err` — the caller still gets a complete `ChaosReport` (and can write
/// `CHAOS.json`) before deciding the exit code.  `Err` means the harness
/// itself broke (a non-injected IO failure).
pub fn run_chaos(opt: &ChaosOptions) -> Result<ChaosReport> {
    fs::create_dir_all(&opt.dir)
        .with_context(|| format!("creating {}", opt.dir.display()))?;
    let retries_before = retry::total_retries();
    let mut rep = ChaosReport::default();
    for cycle in 0..opt.cycles {
        match cycle % 3 {
            0 => campaign_store_cycle(opt, cycle, &mut rep)?,
            1 => conformance_store_cycle(opt, cycle, &mut rep)?,
            _ => coordinator_cycle(opt, cycle, &mut rep)?,
        }
        rep.cycles_run += 1;
    }
    rep.transient_retries = retry::total_retries() - retries_before;
    Ok(rep)
}

/// Serialize `CHAOS.json`; returns byte length.
pub fn write_chaos_json(path: &Path, rep: &ChaosReport) -> Result<usize> {
    let mut doc = BTreeMap::new();
    doc.insert("schema".into(), Value::Str(SCHEMA.into()));
    doc.insert("ok".into(), Value::Bool(rep.ok()));
    doc.insert("cycles".into(), Value::Num(rep.cycles_run as f64));
    doc.insert(
        "divergences".into(),
        Value::Arr(rep.divergences.iter().cloned().map(Value::Str).collect()),
    );
    doc.insert("registry".into(), registry_json(&rep.metrics()));
    crate::obs::report::write_json(path, &Value::Obj(doc))
        .with_context(|| format!("writing {}", path.display()))
}

// --- synthetic golden content ----------------------------------------------

fn synth_cell(cycle: u64, i: u64) -> CellRecord {
    CellRecord {
        hash: fnv1a64(format!("chaos-cell-{cycle}-{i}").as_bytes()),
        key: format!("chaos/c{cycle}/r{i}"),
        instances: 10 + i,
        waste_mean: 0.1 + i as f64 * 1e-3,
        waste_var: 1e-4,
        waste_ci95: 0.005,
        waste_min: 0.05,
        waste_max: 0.2,
        makespan_mean: 5e6 + cycle as f64,
        tr: 4000.0 + i as f64,
    }
}

fn synth_verdict(cycle: u64, i: u64) -> ConformanceRecord {
    ConformanceRecord {
        hash: fnv1a64(format!("chaos-val-{cycle}-{i}").as_bytes()),
        key: format!("chaos/v{cycle}/r{i}"),
        strategy: "NoCkptI".into(),
        law: "exponential".into(),
        multiplier: 1.0 + i as f64 * 0.25,
        tr: 8000.0 + i as f64,
        instances: 40,
        sim_mean: 0.12 + i as f64 * 1e-3,
        sim_ci95: 0.004,
        model: 0.118,
        deviation: 0.002,
        tolerance: 0.04,
        verdict: "pass".into(),
        reason: String::new(),
    }
}

/// Corrupt one full line *interiorly*: still valid JSON, body no longer
/// matching its CRC seal.  Only lines that currently carry a *clean*
/// sealed record qualify — torn fragments left by earlier injected
/// crashes are already unparseable and get *skipped* on reload, which is
/// the wrong oracle for this probe (it must observe a *quarantine*).
/// Returns false if no line qualifies.
fn corrupt_interior(path: &Path, rng: &mut Rng) -> Result<bool> {
    let text = fs::read_to_string(path)?;
    let mut lines: Vec<String> = text.lines().map(str::to_string).collect();
    let clean: Vec<usize> = lines
        .iter()
        .enumerate()
        .filter(|(_, l)| {
            jsonio::parse(l)
                .map(|v| jsonio::check_record(&v) == jsonio::RecordCheck::Clean)
                .unwrap_or(false)
        })
        .map(|(i, _)| i)
        .collect();
    if clean.is_empty() {
        return Ok(false);
    }
    let idx = clean[((rng.f64() * clean.len() as f64) as usize).min(clean.len() - 1)];
    let damaged = lines[idx].replacen("\"key\":\"", "\"key\":\"x", 1);
    if damaged == lines[idx] {
        return Ok(false);
    }
    lines[idx] = damaged;
    fs::write(path, lines.join("\n") + "\n")?;
    Ok(true)
}

// --- store cycles ----------------------------------------------------------

/// Drive `append_missing` to completion under an armed kill schedule,
/// crashing (dropping the store mid-write) and reopening until every
/// record landed.  Returns the number of crashes taken.
fn write_under_chaos<R, S>(
    path: &Path,
    recs: &[R],
    rng: &mut Rng,
    seed: u64,
    rep: &mut ChaosReport,
    open: impl Fn(&Path, bool) -> Result<S>,
    append_missing: impl Fn(&mut S, &[R]) -> Result<()>,
) -> Result<()>
where
    S: TornCount,
{
    for attempt in 0..MAX_ATTEMPTS {
        // Final attempt runs unarmed so the cycle always terminates.
        let armed = if attempt + 1 < MAX_ATTEMPTS {
            let nth = 1 + (rng.f64() * (recs.len() as f64 + 2.0)) as u64;
            let spec = format!(
                "jsonl.tail:mode=torn,nth={nth};\
                 store.append:mode=transient,p=0.15,seed={seed}"
            );
            Some(failpoint::arm(Plan::parse(&spec)?))
        } else {
            None
        };
        let res = (|| -> Result<()> {
            let mut s = open(path, attempt == 0)?;
            rep.torn_tails_repaired += s.torn_lines() as u64;
            append_missing(&mut s, recs)
        })();
        drop(armed);
        match res {
            Ok(()) => return Ok(()),
            Err(e) if is_injected(&e) => {
                rep.crashes_injected += 1;
                rep.resumes += 1;
            }
            Err(e) => return Err(e),
        }
    }
    Err(anyhow!("chaos: store never completed in {MAX_ATTEMPTS} attempts"))
}

/// The torn-tail counter both stores expose.
trait TornCount {
    fn torn_lines(&self) -> usize;
}

impl TornCount for Store {
    fn torn_lines(&self) -> usize {
        self.skipped_lines
    }
}

impl TornCount for ConformanceStore {
    fn torn_lines(&self) -> usize {
        self.skipped_lines
    }
}

fn campaign_store_cycle(
    opt: &ChaosOptions,
    cycle: u64,
    rep: &mut ChaosReport,
) -> Result<()> {
    let n = 10 + (cycle % 6);
    let recs: Vec<CellRecord> = (0..n).map(|i| synth_cell(cycle, i)).collect();
    // Golden: uninterrupted reference store.
    let golden_path = opt.dir.join(format!("store-golden-{cycle}.jsonl"));
    let _ = fs::remove_file(&golden_path);
    {
        let mut g = Store::create(&golden_path)?;
        for r in &recs {
            g.append(r)?;
        }
    }
    // Chaos: same records under torn writes + transient IO.
    let chaos_path = opt.dir.join(format!("store-chaos-{cycle}.jsonl"));
    let _ = fs::remove_file(&chaos_path);
    let mut rng = Rng::stream(opt.seed, cycle.wrapping_mul(3).wrapping_add(1));
    write_under_chaos(
        &chaos_path,
        &recs,
        &mut rng,
        opt.seed ^ cycle,
        rep,
        |p, fresh| if fresh { Store::create(p) } else { Store::open(p) },
        |s, recs| {
            for r in recs {
                if !s.contains(r.hash) {
                    s.append(r)?;
                }
            }
            Ok(())
        },
    )?;
    // Interior corruption: damage a full line, reopen (quarantine), heal.
    if corrupt_interior(&chaos_path, &mut rng)? {
        let mut s = Store::open(&chaos_path)?;
        if s.quarantined_lines == 0 {
            rep.divergences.push(format!(
                "cycle {cycle}: interior corruption in {} was not quarantined",
                chaos_path.display()
            ));
        }
        rep.records_quarantined += s.quarantined_lines as u64;
        for r in &recs {
            if !s.contains(r.hash) {
                s.append(r)?;
            }
        }
    }
    // Record-for-record equivalence.
    let golden = Store::open(&golden_path)?;
    let chaos = Store::open(&chaos_path)?;
    let g: Vec<&CellRecord> = golden.records().collect();
    let c: Vec<&CellRecord> = chaos.records().collect();
    if g != c {
        rep.divergences.push(format!(
            "cycle {cycle}: campaign store diverged ({} vs {} records)",
            g.len(),
            c.len()
        ));
    }
    Ok(())
}

fn conformance_store_cycle(
    opt: &ChaosOptions,
    cycle: u64,
    rep: &mut ChaosReport,
) -> Result<()> {
    let n = 8 + (cycle % 5);
    let recs: Vec<ConformanceRecord> =
        (0..n).map(|i| synth_verdict(cycle, i)).collect();
    let golden_path = opt.dir.join(format!("conf-golden-{cycle}.jsonl"));
    let _ = fs::remove_file(&golden_path);
    {
        let mut g = ConformanceStore::create(&golden_path)?;
        for r in &recs {
            g.append(r)?;
        }
    }
    let chaos_path = opt.dir.join(format!("conf-chaos-{cycle}.jsonl"));
    let _ = fs::remove_file(&chaos_path);
    let mut rng = Rng::stream(opt.seed, cycle.wrapping_mul(3).wrapping_add(2));
    write_under_chaos(
        &chaos_path,
        &recs,
        &mut rng,
        opt.seed ^ cycle,
        rep,
        |p, fresh| {
            if fresh {
                ConformanceStore::create(p)
            } else {
                ConformanceStore::open(p)
            }
        },
        |s, recs| {
            for r in recs {
                if !s.contains(r.hash) {
                    s.append(r)?;
                }
            }
            Ok(())
        },
    )?;
    if corrupt_interior(&chaos_path, &mut rng)? {
        let mut s = ConformanceStore::open(&chaos_path)?;
        if s.quarantined_lines == 0 {
            rep.divergences.push(format!(
                "cycle {cycle}: interior corruption in {} was not quarantined",
                chaos_path.display()
            ));
        }
        rep.records_quarantined += s.quarantined_lines as u64;
        for r in &recs {
            if !s.contains(r.hash) {
                s.append(r)?;
            }
        }
    }
    let golden = ConformanceStore::open(&golden_path)?;
    let chaos = ConformanceStore::open(&chaos_path)?;
    let g: Vec<&ConformanceRecord> = golden.records().collect();
    let c: Vec<&ConformanceRecord> = chaos.records().collect();
    if g != c {
        rep.divergences.push(format!(
            "cycle {cycle}: conformance store diverged ({} vs {} records)",
            g.len(),
            c.len()
        ));
    }
    Ok(())
}

// --- coordinator cycles ----------------------------------------------------

fn coord_config(opt: &ChaosOptions, cycle: u64, tag: &str) -> CoordinatorConfig {
    const KINDS: [PolicyKind; 5] = [
        PolicyKind::IgnorePredictions,
        PolicyKind::WithCkpt,
        PolicyKind::NoCkpt,
        PolicyKind::Instant,
        PolicyKind::WindowEndCkpt,
    ];
    let kind = KINDS[(cycle / 3) as usize % KINDS.len()];
    let dir = opt.dir.join(format!("coord-{tag}-{cycle}"));
    let _ = fs::remove_dir_all(&dir);
    CoordinatorConfig {
        scenario: Scenario {
            platform: Platform { mu: 3500.0, c: 120.0, cp: 60.0, d: 30.0, r: 60.0 },
            predictor: PredictorSpec::paper(0.85, 0.82, 240.0),
            fault_law: Law::Exponential,
            false_pred_law: Law::Exponential,
            fault_model: FaultModel::PlatformRenewal,
            job_size: 0.0, // steps drive the job size
        },
        policy: Policy { kind, tr: 1200.0, tp: 180.0 },
        seconds_per_step: 30.0,
        total_steps: 160,
        ckpt_dir: dir,
        seed: opt.seed ^ cycle,
        log_every: 10,
        selfckpt: Some(SelfCkptOptions::default()),
    }
}

fn coordinator_cycle(
    opt: &ChaosOptions,
    cycle: u64,
    rep: &mut ChaosReport,
) -> Result<()> {
    const PARAMS: usize = 24;
    let golden_cfg = coord_config(opt, cycle, "golden");
    let mut w = SyntheticWorkload::new(PARAMS);
    let golden = coordinator::run(&golden_cfg, &mut w)?;

    let chaos_cfg = coord_config(opt, cycle, "chaos");
    let snaps = SnapshotStore::new(&chaos_cfg.ckpt_dir)?;
    let mut rng = Rng::stream(opt.seed, cycle.wrapping_mul(3));
    let mut resume = None;
    let mut survivor = None;
    for attempt in 0..MAX_ATTEMPTS {
        let armed = if attempt + 1 < MAX_ATTEMPTS {
            // Crash at a randomized pass; also rattle the snapshot writer
            // with transient faults the backoff must absorb.
            let nth = 1 + (rng.f64() * 2.0 * golden.passes as f64) as u64;
            let spec = format!(
                "coord.pass:mode=transient,nth={nth};\
                 snapshot.write:mode=transient,p=0.1,seed={}",
                opt.seed ^ cycle
            );
            Some(failpoint::arm(Plan::parse(&spec)?))
        } else {
            None
        };
        let mut w = SyntheticWorkload::new(PARAMS);
        let res = coordinator::run_from(&chaos_cfg, &mut w, resume.as_ref());
        drop(armed);
        match res {
            Ok(r) => {
                survivor = Some(r);
                break;
            }
            Err(e) if is_injected(&e) => {
                rep.crashes_injected += 1;
                rep.resumes += 1;
                // Resume from whatever self-snapshot the crashed run left
                // (None before the first snapshot ⇒ start over).
                resume = snaps.load()?;
            }
            Err(e) => return Err(e),
        }
    }
    let survivor = survivor
        .ok_or_else(|| anyhow!("chaos: coordinator never completed in {MAX_ATTEMPTS} attempts"))?;
    if survivor.fingerprint() != golden.fingerprint() {
        rep.divergences.push(format!(
            "cycle {cycle}: coordinator fingerprint diverged \
             ({:016x} vs golden {:016x}, policy {:?})",
            survivor.fingerprint(),
            golden.fingerprint(),
            chaos_cfg.policy.kind
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Pure helpers only: armed end-to-end cycles live in
    // `tests/resilience.rs`, which serializes fail-point ownership.

    #[test]
    fn synthetic_records_are_deterministic_and_distinct() {
        assert_eq!(synth_cell(3, 4), synth_cell(3, 4));
        assert_ne!(synth_cell(3, 4).hash, synth_cell(3, 5).hash);
        assert_eq!(synth_verdict(1, 2), synth_verdict(1, 2));
        assert_ne!(synth_verdict(1, 2).hash, synth_verdict(2, 2).hash);
    }

    #[test]
    fn corrupt_interior_breaks_the_seal_but_not_the_json() {
        let dir = std::env::temp_dir()
            .join(format!("ckptwin-chaos-corrupt-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.jsonl");
        {
            let mut s = Store::create(&path).unwrap();
            for i in 0..4 {
                s.append(&synth_cell(0, i)).unwrap();
            }
        }
        let mut rng = Rng::new(7);
        assert!(corrupt_interior(&path, &mut rng).unwrap());
        // Every line still parses; exactly one fails its seal.
        let text = fs::read_to_string(&path).unwrap();
        let mut bad = 0;
        for line in text.lines() {
            let v = jsonio::parse(line).expect("still valid JSON");
            if jsonio::check_record(&v) == jsonio::RecordCheck::Corrupt {
                bad += 1;
            }
        }
        assert_eq!(bad, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn chaos_json_roundtrips_with_schema() {
        let rep = ChaosReport {
            cycles_run: 5,
            crashes_injected: 9,
            resumes: 9,
            torn_tails_repaired: 3,
            records_quarantined: 1,
            transient_retries: 4,
            divergences: vec!["cycle 2: example".into()],
        };
        assert!(!rep.ok());
        let dir = std::env::temp_dir()
            .join(format!("ckptwin-chaos-json-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let path = dir.join("CHAOS.json");
        write_chaos_json(&path, &rep).unwrap();
        let back = jsonio::parse(&fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back.get("schema").and_then(Value::as_str), Some(SCHEMA));
        assert_eq!(back.get("ok"), Some(&Value::Bool(false)));
        assert_eq!(
            back.get("registry")
                .unwrap()
                .get("counters")
                .unwrap()
                .get("chaos.crashes_injected")
                .unwrap()
                .as_usize(),
            Some(9)
        );
        let _ = fs::remove_dir_all(&dir);
    }
}
