//! The coordinator's *own* checkpoint: a checksummed, versioned snapshot
//! of its estimator/replay state, written at a period the repo's own
//! period model chooses — the dogfood half of the resilience subsystem.
//!
//! File format (little-endian, single file `self.snap` in the checkpoint
//! directory):
//! ```text
//! magic   "CKPTWSNP"             8 bytes
//! version u32 (currently 1)      4 bytes
//! body    (fields in order, see `encode`)
//! crc32   u32 over magic..body   4 bytes
//! ```
//! Writes are temp-file + `rename` + fsync, so a crash mid-snapshot leaves
//! the previous snapshot intact — the same atomicity contract as
//! [`crate::coordinator::checkpoint::CheckpointStore`].
//!
//! The snapshot captures the coordinator's full deterministic state at a
//! pass boundary: simulation clock, validated/since counters, how many
//! trace events were consumed (the stream is re-derived from the seed and
//! fast-forwarded on resume), the deterministic `Report` core, the live
//! workload state, *and* the durable checkpoint payload at `validated` —
//! so a resumed run can re-seed its checkpoint store even if retention
//! already evicted that version.

use std::fs;
use std::io::{Read as _, Write as _};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::crc32;

const MAGIC: &[u8; 8] = b"CKPTWSNP";
const VERSION: u32 = 1;

/// Everything the coordinator needs to resume a run at a pass boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct CoordinatorSnapshot {
    /// Guard against resuming under a different configuration
    /// (hash of scenario + policy + steps + seed).
    pub config_fingerprint: u64,
    /// Leader-loop passes completed.
    pub passes: u64,
    /// Simulation clock (s).
    pub sim_t: f64,
    /// Steps secured by the last committed checkpoint.
    pub validated: u64,
    /// Steps done since the last committed checkpoint.
    pub since: u64,
    /// Steps completed in the current regular period.
    pub period_done: u64,
    /// Trace events consumed from the stream (≥ 1: the pre-loop pop).
    pub events_consumed: u64,
    /// Deterministic `Report` counters, in fixed order: n_faults,
    /// n_recoveries, n_reg_ckpts, n_pro_ckpts, n_preds_trusted,
    /// steps_executed, steps_lost.
    pub counters: [u64; 7],
    /// Loss curve so far.
    pub losses: Vec<(u64, f32)>,
    /// Live workload state at the snapshot boundary.
    pub workload: Vec<f32>,
    /// Durable checkpoint payload at `validated` (re-seeds the checkpoint
    /// store on resume if retention evicted it).
    pub ckpt_theta: Vec<f32>,
}

impl CoordinatorSnapshot {
    fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            128 + 12 * self.losses.len()
                + 4 * (self.workload.len() + self.ckpt_theta.len()),
        );
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&self.config_fingerprint.to_le_bytes());
        out.extend_from_slice(&self.passes.to_le_bytes());
        out.extend_from_slice(&self.sim_t.to_bits().to_le_bytes());
        out.extend_from_slice(&self.validated.to_le_bytes());
        out.extend_from_slice(&self.since.to_le_bytes());
        out.extend_from_slice(&self.period_done.to_le_bytes());
        out.extend_from_slice(&self.events_consumed.to_le_bytes());
        for c in self.counters {
            out.extend_from_slice(&c.to_le_bytes());
        }
        out.extend_from_slice(&(self.losses.len() as u64).to_le_bytes());
        for &(step, loss) in &self.losses {
            out.extend_from_slice(&step.to_le_bytes());
            out.extend_from_slice(&loss.to_le_bytes());
        }
        for vec in [&self.workload, &self.ckpt_theta] {
            out.extend_from_slice(&(vec.len() as u64).to_le_bytes());
            for &f in vec.iter() {
                out.extend_from_slice(&f.to_le_bytes());
            }
        }
        out
    }

    fn decode(bytes: &[u8]) -> Result<CoordinatorSnapshot> {
        if bytes.len() < 16 || &bytes[..8] != MAGIC {
            bail!("self-snapshot: bad magic/size");
        }
        let body_end = bytes.len() - 4;
        let stored =
            u32::from_le_bytes(bytes[body_end..].try_into().expect("4 bytes"));
        if crc32(&bytes[..body_end]) != stored {
            bail!("self-snapshot: checksum mismatch");
        }
        let mut cur = Cursor { bytes: &bytes[8..body_end], pos: 0 };
        let version = cur.u32()?;
        if version != VERSION {
            bail!("self-snapshot: unsupported version {version}");
        }
        let config_fingerprint = cur.u64()?;
        let passes = cur.u64()?;
        let sim_t = f64::from_bits(cur.u64()?);
        let validated = cur.u64()?;
        let since = cur.u64()?;
        let period_done = cur.u64()?;
        let events_consumed = cur.u64()?;
        let mut counters = [0u64; 7];
        for c in counters.iter_mut() {
            *c = cur.u64()?;
        }
        let n_losses = cur.u64()? as usize;
        let mut losses = Vec::with_capacity(n_losses.min(1 << 20));
        for _ in 0..n_losses {
            let step = cur.u64()?;
            losses.push((step, cur.f32()?));
        }
        let mut vecs = [Vec::new(), Vec::new()];
        for v in vecs.iter_mut() {
            let n = cur.u64()? as usize;
            v.reserve(n.min(1 << 24));
            for _ in 0..n {
                v.push(cur.f32()?);
            }
        }
        if cur.pos != cur.bytes.len() {
            bail!("self-snapshot: trailing bytes");
        }
        let [workload, ckpt_theta] = vecs;
        Ok(CoordinatorSnapshot {
            config_fingerprint,
            passes,
            sim_t,
            validated,
            since,
            period_done,
            events_consumed,
            counters,
            losses,
            workload,
            ckpt_theta,
        })
    }
}

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Cursor<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.bytes.len())
            .ok_or_else(|| anyhow!("self-snapshot: truncated body"))?;
        let s = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }
}

/// Single-slot atomic snapshot file (`<dir>/self.snap`).
pub struct SnapshotStore {
    path: PathBuf,
    tmp: PathBuf,
}

impl SnapshotStore {
    pub fn new(dir: impl AsRef<Path>) -> Result<SnapshotStore> {
        let dir = dir.as_ref();
        fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        Ok(SnapshotStore {
            path: dir.join("self.snap"),
            tmp: dir.join(".self.snap.tmp"),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Atomically persist `snap` (temp file + rename + fsync).  Fail point
    /// `snapshot.write` fires before any bytes land, so an injected crash
    /// here never produces a torn snapshot.
    pub fn save(&self, snap: &CoordinatorSnapshot) -> Result<()> {
        use crate::resilience::failpoint::{self, Site};
        if let Some(inj) = failpoint::check(Site::SnapshotWrite) {
            inj.trigger()?;
        }
        let mut payload = snap.encode();
        let crc = crc32(&payload);
        payload.extend_from_slice(&crc.to_le_bytes());
        {
            let mut f = fs::File::create(&self.tmp)
                .with_context(|| format!("creating {}", self.tmp.display()))?;
            f.write_all(&payload)?;
            f.sync_all()?;
        }
        fs::rename(&self.tmp, &self.path)
            .with_context(|| format!("publishing {}", self.path.display()))?;
        Ok(())
    }

    /// Load the snapshot, `Ok(None)` when none has been written yet.
    pub fn load(&self) -> Result<Option<CoordinatorSnapshot>> {
        let mut bytes = Vec::new();
        match fs::File::open(&self.path) {
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(None)
            }
            Err(e) => {
                return Err(anyhow!("opening {}: {e}", self.path.display()))
            }
            Ok(mut f) => {
                f.read_to_end(&mut bytes)?;
            }
        }
        CoordinatorSnapshot::decode(&bytes).map(Some)
    }
}

/// Choose the self-snapshot period, in passes, from measured costs and
/// the assumed crash rate — the paper's own first-order machinery
/// ([`crate::model::optimal::daly_period`]) applied to the coordinator
/// itself: μ = crash MTBF, C = R = snapshot cost, all on the wall clock.
/// Returns the *work* portion of the period (`(T − C)/pass_cost`), ≥ 1.
pub fn plan_period_passes(
    mean_snap_secs: f64,
    mean_pass_secs: f64,
    crash_mtbf_passes: f64,
) -> u64 {
    let pass = mean_pass_secs.max(1e-9);
    let c = mean_snap_secs.max(1e-9);
    let p = crate::config::Platform {
        mu: crash_mtbf_passes.max(1.0) * pass,
        c,
        cp: c,
        d: 0.0,
        r: c,
    };
    let t = crate::model::optimal::daly_period(&p);
    (((t - c) / pass).round() as u64).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CoordinatorSnapshot {
        CoordinatorSnapshot {
            config_fingerprint: 0xDEAD_BEEF_CAFE_F00D,
            passes: 321,
            sim_t: 12_345.678,
            validated: 120,
            since: 7,
            period_done: 3,
            events_consumed: 42,
            counters: [5, 5, 12, 3, 4, 140, 13],
            losses: vec![(10, 3.5), (20, 2.25), (127, 1.125)],
            workload: vec![127.0, 0.5, -0.25],
            ckpt_theta: vec![120.0, 0.75],
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("ckptwin-snap-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn roundtrip() {
        let store = SnapshotStore::new(tmpdir("rt")).unwrap();
        assert!(store.load().unwrap().is_none());
        let snap = sample();
        store.save(&snap).unwrap();
        assert_eq!(store.load().unwrap().unwrap(), snap);
        // Overwrite wins.
        let mut snap2 = sample();
        snap2.passes = 999;
        snap2.losses.clear();
        store.save(&snap2).unwrap();
        assert_eq!(store.load().unwrap().unwrap(), snap2);
    }

    #[test]
    fn corruption_and_truncation_detected() {
        let store = SnapshotStore::new(tmpdir("corrupt")).unwrap();
        store.save(&sample()).unwrap();
        let clean = fs::read(store.path()).unwrap();
        // Flip a body byte.
        let mut bad = clean.clone();
        bad[20] ^= 0x40;
        fs::write(store.path(), &bad).unwrap();
        assert!(store.load().is_err());
        // Truncate.
        fs::write(store.path(), &clean[..clean.len() - 9]).unwrap();
        assert!(store.load().is_err());
        // Garbage magic.
        fs::write(store.path(), b"NOTASNAP-and-more").unwrap();
        assert!(store.load().is_err());
        // Restore the clean bytes: loads again.
        fs::write(store.path(), &clean).unwrap();
        assert_eq!(store.load().unwrap().unwrap(), sample());
    }

    #[test]
    fn planner_period_is_sane_and_monotone_in_mtbf() {
        // Cheap snapshots + rare crashes → long periods; expensive
        // snapshots + frequent crashes → short (but ≥ 1).
        let rare = plan_period_passes(0.001, 0.01, 10_000.0);
        let frequent = plan_period_passes(0.001, 0.01, 10.0);
        assert!(rare > frequent, "{rare} vs {frequent}");
        assert!(frequent >= 1);
        // Degenerate measurements still give a usable period.
        assert!(plan_period_passes(0.0, 0.0, 0.0) >= 1);
        // Daly first-order shape: doubling MTBF scales the work period by
        // ~sqrt(2) when C ≪ μ.
        let a = plan_period_passes(0.01, 0.01, 1_000.0);
        let b = plan_period_passes(0.01, 0.01, 2_000.0);
        let ratio = b as f64 / a as f64;
        assert!(ratio > 1.2 && ratio < 1.7, "{ratio}");
    }
}
