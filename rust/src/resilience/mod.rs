//! Resilience subsystem: deterministic fault injection, crash–resume
//! equivalence checking, and the coordinator's self-checkpointing.
//!
//! Four layers, from mechanism to harness:
//!
//! * [`failpoint`] — a seeded registry of named fail points threaded
//!   through the store, JSONL appender, scheduler workers, trace pool,
//!   and coordinator.  Zero-cost when disarmed (one relaxed atomic load);
//!   armed from the CLI via `--inject "site:p=0.01,seed=42"`.
//! * [`retry`] — bounded exponential backoff with deterministic jitter
//!   for transient IO faults; the retry schedule is a pure function of
//!   (seed, attempt).
//! * [`snapshot`] — the coordinator's *own* checksummed, versioned
//!   snapshot file, written at a period chosen by the repo's own
//!   checkpoint-period model from measured snapshot cost and the assumed
//!   crash rate (the subsystem dogfoods the paper it reproduces).
//! * [`chaos`] — the crash–resume equivalence gate behind
//!   `ckptwin chaos`: a golden uninterrupted run compared
//!   record-for-record (and fingerprint-for-fingerprint) against runs
//!   that are repeatedly killed and resumed, including torn partial-line
//!   writes and interior corruption.
//!
//! Design notes live in `DESIGN.md` §Resilience.

pub mod chaos;
pub mod failpoint;
pub mod retry;
pub mod snapshot;
