//! Bounded exponential backoff with deterministic jitter.
//!
//! The retry *schedule* — how long attempt `n` waits — is a pure function
//! of `(seed, attempt)` (see [`Backoff::delay_ms`]), so a chaos run that
//! injects transient IO faults replays bit-identically: same fault plan,
//! same retries, same final store.
//!
//! Only *transient* errors are retried.  The vendored `anyhow` carries no
//! error types to downcast, so transience is a message classification:
//! injected transient faults embed [`failpoint::TRANSIENT_MARK`]; every
//! other error (real IO failures included) is treated as permanent and
//! surfaces immediately.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::Result;

use super::failpoint;
use crate::sim::rng::Rng;

/// Process-wide count of retry sleeps taken (chaos telemetry).
static RETRIES: AtomicU64 = AtomicU64::new(0);

/// Total retries performed since process start.
pub fn total_retries() -> u64 {
    RETRIES.load(Ordering::Relaxed)
}

/// Is `e` a retryable transient fault?
pub fn is_transient(e: &anyhow::Error) -> bool {
    // `.context(..)` prepends text, so match anywhere in the chain.
    e.to_string().contains(failpoint::TRANSIENT_MARK)
}

/// Bounded exponential backoff policy (copyable, all-public knobs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Backoff {
    /// Delay before the 2nd attempt (ms); doubles per further attempt.
    pub base_ms: u64,
    /// Upper bound on any single delay (ms).
    pub cap_ms: u64,
    /// Total attempts (first try included).
    pub attempts: u32,
    /// Jitter seed — the schedule is pure in `(seed, attempt)`.
    pub seed: u64,
}

impl Default for Backoff {
    fn default() -> Self {
        Backoff { base_ms: 2, cap_ms: 40, attempts: 4, seed: 0x5eed_ba5e }
    }
}

impl Backoff {
    /// Sleep taken after failed attempt `attempt` (1-based): bounded
    /// exponential `min(cap, base·2^(attempt-1))`, scaled by a
    /// deterministic jitter factor in `[0.5, 1.0)` drawn from
    /// `Rng::stream(seed, attempt)`.  Pure: no clocks, no global RNG.
    pub fn delay_ms(&self, attempt: u32) -> u64 {
        let exp = attempt.saturating_sub(1).min(16);
        let raw = self.base_ms.saturating_mul(1u64 << exp).min(self.cap_ms);
        let jitter = 0.5 + 0.5 * Rng::stream(self.seed, attempt as u64).f64();
        ((raw as f64) * jitter).floor().max(1.0) as u64
    }

    /// Run `op` until it succeeds, it fails permanently, or attempts are
    /// exhausted.  `op` receives the 1-based attempt number.  Transient
    /// failures sleep [`Backoff::delay_ms`] between attempts and bump the
    /// global retry counter.
    pub fn run<T>(&self, mut op: impl FnMut(u32) -> Result<T>) -> Result<T> {
        let mut attempt = 1u32;
        loop {
            match op(attempt) {
                Ok(v) => return Ok(v),
                Err(e) if attempt < self.attempts && is_transient(&e) => {
                    RETRIES.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(std::time::Duration::from_millis(
                        self.delay_ms(attempt),
                    ));
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::anyhow;

    #[test]
    fn delay_is_pure_in_seed_and_attempt() {
        let b = Backoff::default();
        for attempt in 1..=8 {
            // Same (seed, attempt) → same delay, across fresh policy values.
            assert_eq!(b.delay_ms(attempt), Backoff::default().delay_ms(attempt));
        }
        // A different seed changes at least one delay in the schedule.
        let other = Backoff { seed: 1234, ..Backoff::default() };
        let a: Vec<u64> = (1..=8).map(|n| b.delay_ms(n)).collect();
        let c: Vec<u64> = (1..=8).map(|n| other.delay_ms(n)).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn delay_is_bounded_exponential() {
        let b = Backoff { base_ms: 2, cap_ms: 40, attempts: 10, seed: 9 };
        for attempt in 1..=20 {
            let d = b.delay_ms(attempt);
            let raw = b.base_ms.saturating_mul(1u64 << attempt.saturating_sub(1).min(16)).min(b.cap_ms);
            // Jitter keeps the delay within [raw/2, raw] (and ≥ 1ms).
            assert!(d >= (raw / 2).max(1) && d <= raw, "attempt {attempt}: {d} vs raw {raw}");
        }
        // The cap binds for late attempts.
        assert!(b.delay_ms(20) <= b.cap_ms);
    }

    #[test]
    fn run_retries_only_transient_errors() {
        let b = Backoff { base_ms: 1, cap_ms: 2, attempts: 3, seed: 0 };
        // Transient twice, then success.
        let mut calls = 0;
        let out: Result<u32> = b.run(|attempt| {
            calls += 1;
            if attempt < 3 {
                Err(anyhow!("{} at store.append (hit {attempt})", failpoint::TRANSIENT_MARK))
            } else {
                Ok(7)
            }
        });
        assert_eq!(out.unwrap(), 7);
        assert_eq!(calls, 3);

        // Permanent errors surface immediately.
        let mut calls = 0;
        let out: Result<u32> = b.run(|_| {
            calls += 1;
            Err(anyhow!("disk on fire"))
        });
        assert!(out.is_err());
        assert_eq!(calls, 1);

        // Transient every time: attempts exhausted, last error returned.
        let mut calls = 0;
        let out: Result<u32> = b.run(|attempt| {
            calls += 1;
            Err(anyhow!("{} at store.append (hit {attempt})", failpoint::TRANSIENT_MARK))
        });
        let msg = out.unwrap_err().to_string();
        assert!(is_transient_msg(&msg));
        assert_eq!(calls, 3);
    }

    fn is_transient_msg(msg: &str) -> bool {
        msg.contains(failpoint::TRANSIENT_MARK)
    }

    #[test]
    fn transient_classification_survives_context() {
        use anyhow::Context as _;
        let e: Result<()> = Err(anyhow!("{} at jsonl.tail (hit 1)", failpoint::TRANSIENT_MARK));
        let wrapped = e.context("appending cell record").unwrap_err();
        assert!(is_transient(&wrapped));
        let plain: anyhow::Error = anyhow!("permission denied");
        assert!(!is_transient(&plain));
    }
}
