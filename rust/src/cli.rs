//! Tiny CLI argument parser (offline environment: no clap).
//!
//! Grammar: `ckptwin <subcommand> [--key value | --key=value | --flag] ...`

use std::collections::{BTreeMap, BTreeSet};
use std::str::FromStr;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    kv: BTreeMap<String, String>,
    flags: BTreeSet<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.kv.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .is_some_and(|next| !next.starts_with("--"))
                {
                    args.kv.insert(name.to_string(), iter.next().unwrap());
                } else {
                    args.flags.insert(name.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Typed option: `--key value`.
    pub fn get<T: FromStr>(&self, key: &str) -> Option<T> {
        self.kv.get(key).and_then(|v| v.parse().ok())
    }

    /// Typed option with default.
    pub fn get_or<T: FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).unwrap_or(default)
    }

    /// Raw string option.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(|s| s.as_str())
    }

    /// Boolean flag (`--flag`).
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains(key) || self.kv.contains_key(key)
    }

    /// Every option/flag name present on the command line, in sorted
    /// order (kv options first, then bare flags). Lets subcommands
    /// reject typo'd keys (`campaign::overrides::check_keys`) instead of
    /// silently ignoring them.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.kv
            .keys()
            .map(String::as_str)
            .chain(self.flags.iter().map(String::as_str))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("figure --id 4 --instances=20 --best-period");
        assert_eq!(a.subcommand.as_deref(), Some("figure"));
        assert_eq!(a.get::<u8>("id"), Some(4));
        assert_eq!(a.get::<usize>("instances"), Some(20));
        assert!(a.has("best-period"));
        assert!(!a.has("missing"));
    }

    #[test]
    fn trailing_flag_and_positional() {
        let a = parse("simulate config.toml --verbose");
        assert_eq!(a.positional, vec!["config.toml"]);
        assert!(a.has("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse("table");
        assert_eq!(a.get_or("id", 4u8), 4);
        assert_eq!(a.get_or("instances", 100usize), 100);
    }
}
