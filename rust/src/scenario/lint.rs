//! `ckptwin lint` — every diagnostic for a `.ckpt` file, before a sweep
//! burns CPU.
//!
//! Unlike [`compile`](super::compile), which stops at the first error,
//! lint collects *all* schema errors (unknown sections/keys with
//! nearest-match suggestions, bad registry ids, out-of-range params,
//! expectation mismatches) and then — when the file compiles — runs the
//! `validate::domain` classifier over every compiled cell as a warning
//! pre-pass: cells that would be classified out of the formulas'
//! validity domain (WindowsOverlap, BeyondFirstOrder, JobTooShort,
//! NoClosedForm, …) are reported per reason with counts. Those are
//! warnings, not errors: classified cells are a first-class conformance
//! outcome, but a suite that is *mostly* out of domain is usually a
//! mis-set axis.

use super::ast::ScenarioFile;
use super::compile::{self, CompiledSuite, SuiteKind};
use crate::validate::domain::{self, Inapplicable};
use crate::validate::SweepOptions;

/// One lint finding with its source line (0 = file-level).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diag {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for Diag {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.line > 0 {
            write!(f, "line {}: {}", self.line, self.msg)
        } else {
            f.write_str(&self.msg)
        }
    }
}

/// Everything lint found. `errors` empty ⇒ the file compiles and is
/// runnable; `warnings` are advisory (domain pre-classification).
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    pub errors: Vec<Diag>,
    pub warnings: Vec<Diag>,
    /// Compiled cell count (0 when the file does not compile).
    pub cells: usize,
    /// Suite name, when the file compiles.
    pub name: Option<String>,
}

impl LintReport {
    pub fn ok(&self) -> bool {
        self.errors.is_empty()
    }
}

/// Classify every compiled cell without simulating, and fold the
/// out-of-domain reasons into per-reason warning counts.
fn domain_warnings(suite: &CompiledSuite, out: &mut Vec<Diag>) {
    let tolerance = SweepOptions::default().tolerance;
    // Campaign suites are linted as their m = 1.0 conformance shadow:
    // same cells, platform-renewal fault model, the model the sweep
    // would price them against.
    let cells = match suite.kind {
        SuiteKind::Conformance => suite.val_cells(),
        SuiteKind::Campaign => crate::validate::expand_cells(&suite.grid, &[1.0]),
    };
    let total = cells.len();
    let mut counts: Vec<(Inapplicable, usize)> = Vec::new();
    for vc in &cells {
        let kind = vc.cell.strategy.kind();
        // Mirrors validate::evaluate_cell: no closed form ⇒ no policy
        // instantiation (this also keeps lint cheap for the BestPeriod
        // twins, whose policy is a brute-force search).
        let reason = if kind.grid_strategy().is_none() {
            Some(Inapplicable::NoClosedForm)
        } else {
            let sc = vc.scenario();
            let pol = vc.cell.strategy.policy(&sc);
            domain::classify(&sc, kind, pol.tr * vc.multiplier, pol.tp, &tolerance).err()
        };
        if let Some(reason) = reason {
            match counts.iter_mut().find(|(r, _)| *r == reason) {
                Some((_, n)) => *n += 1,
                None => counts.push((reason, 1)),
            }
        }
    }
    for (reason, n) in counts {
        out.push(Diag {
            line: 0,
            msg: format!(
                "{n}/{total} cells classify {} (reported, never failed)",
                reason.label()
            ),
        });
    }
}

/// Lint scenario text: parse, sweep the schema for every unknown
/// section/key, compile, pre-classify.
pub fn lint_str(text: &str) -> LintReport {
    let mut report = LintReport::default();
    let file = match ScenarioFile::parse(text) {
        Ok(f) => f,
        Err(e) => {
            report.errors.push(Diag { line: e.line, msg: e.msg });
            return report;
        }
    };
    // Comprehensive schema sweep: collect every unknown section and key
    // (compile would stop at the first).
    for section in &file.sections {
        match compile::section_keys(&section.name) {
            None => {
                let msg = match crate::campaign::overrides::nearest(
                    &section.name,
                    compile::SECTIONS.iter().copied(),
                ) {
                    Some(s) => format!(
                        "unknown section '[{}]' (did you mean '[{s}]'?)",
                        section.name
                    ),
                    None => format!("unknown section '[{}]'", section.name),
                };
                report.errors.push(Diag { line: section.line, msg });
            }
            Some(allowed) => {
                for entry in &section.entries {
                    if !allowed.contains(&entry.key.as_str()) {
                        let msg = match crate::campaign::overrides::nearest(
                            &entry.key,
                            allowed.iter().copied(),
                        ) {
                            Some(s) => format!(
                                "unknown key '{}' in [{}] (did you mean '{s}'?)",
                                entry.key, section.name
                            ),
                            None => {
                                format!("unknown key '{}' in [{}]", entry.key, section.name)
                            }
                        };
                        report.errors.push(Diag { line: entry.line, msg });
                    }
                }
            }
        }
    }
    if !report.errors.is_empty() {
        return report;
    }
    match compile::compile(&file) {
        Err(e) => report.errors.push(Diag { line: e.line, msg: e.msg }),
        Ok(suite) => {
            report.cells = suite.cell_count();
            report.name = Some(suite.name.clone());
            domain_warnings(&suite, &mut report.warnings);
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_multiple_schema_errors() {
        let r = lint_str("[suite]\nname = t\n\n[axes]\nprocz = 1\nstrategis = Daly\n");
        assert!(!r.ok());
        assert_eq!(r.errors.len(), 2);
        assert_eq!(r.errors[0].line, 5);
        assert_eq!(r.errors[1].line, 6);
        assert!(r.errors[0].msg.contains("did you mean 'procs'"), "{}", r.errors[0]);
    }

    #[test]
    fn clean_conformance_suite_warns_about_classified_cells() {
        let r = lint_str("[suite]\nname = census\nkind = conformance\nbase = smoke\n");
        assert!(r.ok(), "{:?}", r.errors);
        assert_eq!(r.cells, 72);
        // The tier-1 census has 26 classified cells: 24 no_closed_form
        // + 2 proactive_period_outside_window (pinned in
        // tests/conformance.rs).
        let total: usize = r
            .warnings
            .iter()
            .map(|w| w.msg.split('/').next().unwrap().parse::<usize>().unwrap())
            .sum();
        assert_eq!(total, 26, "{:?}", r.warnings);
        assert!(r.warnings.iter().any(|w| w.msg.contains("no_closed_form")));
    }

    #[test]
    fn compile_errors_flow_through() {
        let r = lint_str("[suite]\nname = t\nbase = nope\n");
        assert!(!r.ok());
        assert!(r.errors[0].msg.contains("unknown base"), "{}", r.errors[0]);
    }
}
