//! `ckptwin explain` — why a conformance cell passed, failed, or was
//! classified.
//!
//! [`explain_cell`] re-derives one cell's verdict exactly as
//! `validate::evaluate_cell` does (same guards, same paired seeds, same
//! trace-pool replay — the sim statistics are bit-identical, pinned by
//! `tests/scenario.rs`), but keeps the intermediate quantities:
//! the [`Inapplicable`] guard that fired, rendered as a sentence with
//! the measured value that tripped it, and the 5-term priced tolerance
//! broken out term by term ([`tolerance_terms`]; the terms sum — in
//! order — to `domain::tolerance` bit-for-bit).

use crate::campaign::TracePool;
use crate::config::Scenario;
use crate::sim::engine::simulate_from;
use crate::stats::Welford;
use crate::strategy::{Policy, PolicyKind};
use crate::validate::domain::{
    self, Inapplicable, TolerancePolicy, FIRST_ORDER_MAX, MIN_PERIODS, OVERLAP_MAX,
    PLATFORM_RATE_TOL,
};
use crate::validate::{ValCell, Verdict};

/// One priced term of the tolerance budget.
#[derive(Clone, Copy, Debug)]
pub struct ToleranceTerm {
    pub label: &'static str,
    pub value: f64,
}

/// The 5 tolerance terms, in the exact order `domain::tolerance` sums
/// them — so `terms.iter().fold(0.0, |a, t| a + t.value)` is
/// bit-identical to the priced tolerance.
pub fn tolerance_terms(
    policy: &TolerancePolicy,
    sc: &Scenario,
    kind: PolicyKind,
    tr: f64,
    ci95: f64,
) -> [ToleranceTerm; 5] {
    let x = tr / sc.platform.mu;
    [
        ToleranceTerm { label: "abs_floor", value: policy.abs_floor },
        ToleranceTerm {
            label: "tail_spread",
            value: policy.tail_floor * (sc.fault_law.cv2() - 1.0).clamp(0.0, 2.0),
        },
        ToleranceTerm { label: "curvature", value: policy.curvature * x * x },
        ToleranceTerm {
            label: "renewal_excess",
            value: domain::renewal_excess_waste(sc, kind, tr),
        },
        ToleranceTerm { label: "sampling_ci", value: policy.ci_mult * ci95 },
    ]
}

/// One sentence per [`Inapplicable`] variant, carrying the measured
/// quantity that tripped the guard. Defined for *every* variant (even
/// ones `classify` cannot reach for a given cell) so the transcript
/// goldens in `tests/scenario.rs` can pin each one.
pub fn guard_sentence(
    reason: Inapplicable,
    sc: &Scenario,
    kind: PolicyKind,
    tr: f64,
    tp: f64,
    policy: &TolerancePolicy,
) -> String {
    use crate::model::waste::Inapplicability as M;
    let pf = &sc.platform;
    match reason {
        Inapplicable::Model(M::PeriodWithinCheckpoint) => format!(
            "structural guard period_within_checkpoint: T_R = {tr:.3} <= C = {} leaves no room for work in a period",
            pf.c
        ),
        Inapplicable::Model(M::MtbfWithinRecovery) => format!(
            "structural guard mtbf_within_recovery: platform MTBF mu = {:.3} <= D + R = {} — the platform re-faults before it finishes recovering",
            pf.mu,
            pf.d + pf.r
        ),
        Inapplicable::Model(M::ZeroPrecision) => "structural guard zero_precision: predictor precision p = 0 — every prediction is false, and Eqs. (4)/(10)/(14) divide by p*mu".to_string(),
        Inapplicable::Model(M::ProactivePeriodOutsideWindow) => format!(
            "structural guard proactive_period_outside_window: T_P = {tp:.3} does not satisfy Cp = {} <= T_P <= I = {}",
            pf.cp, sc.predictor.window
        ),
        Inapplicable::Model(M::WasteOutOfRange) => "structural guard waste_out_of_range: the closed form evaluates outside [0, 1] at this period".to_string(),
        Inapplicable::NoClosedForm => "the paper derives no closed form for this execution mode (ExactPred / WindowEndCkpt / QTrust); there is no model value to compare against".to_string(),
        Inapplicable::BeyondFirstOrder => format!(
            "regime guard beyond_first_order: T_R/mu = {:.4} > {FIRST_ORDER_MAX} — the truncated O((T_R/mu)^2) terms of the first-order expansion dominate",
            tr / pf.mu
        ),
        Inapplicable::JobTooShort => format!(
            "regime guard job_too_short: the job holds {:.2} regular periods < {MIN_PERIODS} — no steady state for the asymptotic waste model",
            sc.job_size / tr
        ),
        Inapplicable::WindowsOverlap => format!(
            "regime guard windows_overlap: (I_max + Cp)/mu_P = {:.4} > {OVERLAP_MAX} — overlapping prediction windows, which the analysis assumes away (paper §2.3)",
            (sc.predictor.max_window() + pf.cp) / sc.predictor.mu_p(pf.mu)
        ),
        Inapplicable::TransientFaultModel => format!(
            "regime guard transient_fault_model: fresh per-processor {} traces carry the superposed infant-mortality transient the 1/mu rate assumption misses",
            sc.fault_law.label()
        ),
        Inapplicable::HorizonTooShort => format!(
            "regime guard horizon_too_short: the finite-horizon renewal excess alone is {:.4} > max_renewal_excess = {} — the job never reaches this heavy-tailed law's renewal rate",
            domain::renewal_excess_waste(sc, kind, tr),
            policy.max_renewal_excess
        ),
        Inapplicable::NonUniformWindow => format!(
            "predictor-model guard non_uniform_window: {} varies the window length per announcement, so the fixed-I terms of Eqs. (4)/(10)/(14) have no single I",
            sc.predictor.model.label()
        ),
        Inapplicable::NoisyWindowPlacement => format!(
            "predictor-model guard noisy_window_placement: {} places windows with noise, so the effective recall sits below the nominal r = {} the formulas use",
            sc.predictor.model.label(),
            sc.predictor.recall
        ),
        Inapplicable::ConfidenceClasses => format!(
            "predictor-model guard confidence_classes: {} attaches per-announcement trust, while the q = 1 formulas assume every prediction is acted on",
            sc.predictor.model.label()
        ),
        Inapplicable::PlatformRateNonconforming => format!(
            "scale guard platform_rate_nonconforming: the measured superposed platform fault rate deviates from the 1/mu = {:.3e} approximation by more than {PLATFORM_RATE_TOL} (a-posteriori scale-check verdict)",
            1.0 / pf.mu
        ),
    }
}

/// Everything `explain` knows about one conformance cell.
#[derive(Clone, Debug)]
pub struct Explanation {
    pub key: String,
    pub strategy: String,
    pub law: String,
    pub multiplier: f64,
    /// Regular period actually compared (NaN when no closed form exists,
    /// so no policy was instantiated).
    pub tr: f64,
    pub instances: u64,
    pub sim_mean: f64,
    pub sim_ci95: f64,
    pub model: f64,
    pub deviation: f64,
    pub tolerance: f64,
    pub verdict: Verdict,
    /// The guard sentence, when the cell classified [`Inapplicable`].
    pub guard: Option<String>,
    /// The 5 priced tolerance terms (empty when inapplicable).
    pub terms: Vec<ToleranceTerm>,
}

/// Re-derive one cell's verdict, keeping the intermediates. Mirrors
/// `validate::evaluate_cell` step for step: same early-outs, same
/// paired seeds, same pool replay — the statistics are bit-identical to
/// what a sweep at the same instance count stores.
pub fn explain_cell(vc: &ValCell, instances: usize, policy: &TolerancePolicy) -> Explanation {
    let sc = vc.scenario();
    let kind = vc.cell.strategy.kind();
    let mut ex = Explanation {
        key: vc.key(),
        strategy: vc.cell.strategy.to_string(),
        law: vc.cell.fault_law.label(),
        multiplier: vc.multiplier,
        tr: f64::NAN,
        instances: 0,
        sim_mean: f64::NAN,
        sim_ci95: f64::NAN,
        model: f64::NAN,
        deviation: f64::NAN,
        tolerance: f64::NAN,
        verdict: Verdict::Inapplicable(Inapplicable::NoClosedForm),
        guard: None,
        terms: Vec::new(),
    };
    if kind.grid_strategy().is_none() {
        ex.guard = Some(guard_sentence(
            Inapplicable::NoClosedForm,
            &sc,
            kind,
            f64::NAN,
            f64::NAN,
            policy,
        ));
        return ex;
    }
    let pol = vc.cell.strategy.policy(&sc);
    let tr = pol.tr * vc.multiplier;
    ex.tr = tr;
    let model = match domain::classify(&sc, kind, tr, pol.tp, policy) {
        Err(reason) => {
            ex.verdict = Verdict::Inapplicable(reason);
            ex.guard = Some(guard_sentence(reason, &sc, kind, tr, pol.tp, policy));
            return ex;
        }
        Ok(m) => m,
    };
    let pol = Policy { kind, tr, tp: pol.tp };
    let mut pool = TracePool::new();
    let mut waste = Welford::new();
    for i in 0..instances.max(1) {
        let seed = vc.cell.instance_seed(i as u64);
        let out = simulate_from(&sc, &pol, 1.0, seed, pool.replay(vc.pool_hash, &sc, seed));
        waste.push(out.waste());
    }
    ex.instances = waste.len() as u64;
    ex.sim_mean = waste.mean();
    ex.sim_ci95 = waste.ci95();
    ex.model = model;
    ex.deviation = (waste.mean() - model).abs();
    ex.tolerance = domain::tolerance(policy, &sc, kind, tr, waste.ci95());
    ex.terms = tolerance_terms(policy, &sc, kind, tr, waste.ci95()).to_vec();
    ex.verdict =
        if ex.deviation <= ex.tolerance { Verdict::Pass } else { Verdict::Fail };
    ex
}

impl Explanation {
    /// Deterministic multi-line transcript (the `ckptwin explain`
    /// output; goldens pinned in `tests/scenario.rs`).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("cell      {}\n", self.key));
        out.push_str(&format!(
            "scenario  strategy {} | law {} | multiplier {}\n",
            self.strategy, self.law, self.multiplier
        ));
        out.push_str(&format!("verdict   {}\n", self.verdict.label()));
        if let Some(guard) = &self.guard {
            out.push_str(&format!("  guard: {guard}\n"));
            if self.tr.is_finite() {
                out.push_str(&format!("  period T_R = {:.3} (classified before simulation)\n", self.tr));
            }
            return out;
        }
        out.push_str(&format!(
            "  period T_R = {:.3} (analytic optimum x {})\n",
            self.tr, self.multiplier
        ));
        out.push_str(&format!(
            "  simulated waste {:.6} +/- {:.6} (CI95, {} instances, paired seeds)\n",
            self.sim_mean, self.sim_ci95, self.instances
        ));
        out.push_str(&format!("  model waste     {:.6}\n", self.model));
        out.push_str(&format!(
            "  deviation       {:.6} {} tolerance {:.6}\n",
            self.deviation,
            if self.deviation <= self.tolerance { "<=" } else { ">" },
            self.tolerance
        ));
        out.push_str("  tolerance terms:\n");
        let mut total = 0.0;
        for t in &self.terms {
            total += t.value;
            out.push_str(&format!("    {:<16}{:.6}\n", t.label, t.value));
        }
        out.push_str(&format!("    {:<16}{total:.6}\n", "total"));
        out
    }
}
