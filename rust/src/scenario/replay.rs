//! `ckptwin replay <store> <cell-hash> [--verify]` — re-run any stored
//! campaign/conformance cell from its hash and diff the fresh record
//! field-for-field against the stored one.
//!
//! The cell key grammar (see
//! [`campaign::Cell::key`](crate::campaign::Cell) /
//! [`validate::ValCell::key`](crate::validate::ValCell)) is total: it
//! names every input that shapes a record — platform size, C_p ratio,
//! laws, predictor spec + model, strategy id + params, scale, shards,
//! fault model, multiplier.  [`parse_cell_key`] inverts it, and then
//! *re-renders* the rebuilt cell's key and requires it to be
//! byte-identical to the input — any float-formatting or grammar drift
//! is an error here, never a silent wrong-cell replay.  Paired seeds
//! derive from the key's trace hash, so a re-run at the stored instance
//! count reproduces the record bit-for-bit (the CI replay-verify smoke
//! pins this).

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::campaign::{self, Cell, CellRecord, Grid};
use crate::config::{FaultModel, PredModel, PredictorSpec};
use crate::sim::distribution::Law;
use crate::strategy::StrategyId;
use crate::util::split_top_level_on;
use crate::validate::{self, store::ConformanceRecord, SweepOptions, ValCell};

/// Which store format a JSONL file holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoreKind {
    Campaign,
    Conformance,
}

/// Decide a store's kind from its first parseable record: conformance
/// records carry a `verdict` field, campaign records never do.
pub fn sniff_store_kind(path: &Path) -> Result<StoreKind> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading store {}", path.display()))?;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Ok(v) = crate::jsonio::parse(line) {
            return Ok(if v.get("verdict").is_some() {
                StoreKind::Conformance
            } else {
                StoreKind::Campaign
            });
        }
    }
    bail!("{}: no parseable records — cannot tell campaign from conformance", path.display())
}

/// Ordered field cursor over a `;`-separated key (top-level split: the
/// separators inside `mixedwin(i1=…;i2=…)` or `QTrust(q=…)` stay put).
struct Fields<'a> {
    fields: Vec<(&'a str, &'a str)>,
    at: usize,
}

impl<'a> Fields<'a> {
    fn parse(key: &'a str) -> Result<Fields<'a>> {
        let mut fields = Vec::new();
        for piece in split_top_level_on(key, ';') {
            let (k, v) = piece
                .split_once('=')
                .ok_or_else(|| anyhow!("bad key field '{piece}' in '{key}'"))?;
            fields.push((k, v));
        }
        Ok(Fields { fields, at: 0 })
    }

    /// Consume the next field, which must be named `name`.
    fn expect(&mut self, name: &str) -> Result<&'a str> {
        let (k, v) = *self
            .fields
            .get(self.at)
            .ok_or_else(|| anyhow!("key ended early: expected field '{name}'"))?;
        if k != name {
            bail!("expected key field '{name}', found '{k}'");
        }
        self.at += 1;
        Ok(v)
    }

    /// Consume the next field iff it is named `name`.
    fn accept(&mut self, name: &str) -> Option<&'a str> {
        match self.fields.get(self.at) {
            Some(&(k, v)) if k == name => {
                self.at += 1;
                Some(v)
            }
            _ => None,
        }
    }

    fn finish(&self) -> Result<()> {
        if self.at != self.fields.len() {
            bail!("trailing key fields: '{}…'", self.fields[self.at].0);
        }
        Ok(())
    }
}

fn num<T: std::str::FromStr>(what: &str, raw: &str) -> Result<T> {
    raw.trim().parse().map_err(|_| anyhow!("bad {what} '{raw}' in cell key"))
}

fn parse_law(what: &str, raw: &str) -> Result<Law> {
    Law::parse(raw).ok_or_else(|| anyhow!("bad {what} '{raw}' in cell key"))
}

/// Parse the leading (campaign) portion of a key off the cursor.
fn parse_cell_fields(f: &mut Fields<'_>) -> Result<Cell> {
    let procs: u64 = num("procs", f.expect("procs")?)?;
    let cp_ratio: f64 = num("cp ratio", f.expect("cp")?)?;
    let fault_law = parse_law("fault law", f.expect("law")?)?;
    let false_pred_law = parse_law("false-prediction law", f.expect("fp")?)?;
    let scale: f64 = num("scale", f.expect("scale")?)?;
    let shards: u32 = match f.accept("shards") {
        Some(v) => num("shard count", v)?,
        None => 1,
    };
    let precision: f64 = num("precision", f.expect("p")?)?;
    let recall: f64 = num("recall", f.expect("r")?)?;
    let window: f64 = num("window", f.expect("I")?)?;
    let model = match f.accept("pm") {
        Some(v) => PredModel::parse_label(v).map_err(|e| anyhow!(e))?,
        None => PredModel::Paper,
    };
    let strategy = StrategyId::parse(f.expect("strat")?).map_err(|e| anyhow!(e))?;
    let predictor = PredictorSpec { recall, precision, window, model };
    Ok(Cell::new(procs, cp_ratio, fault_law, false_pred_law, predictor, strategy, scale)
        .with_shards(shards))
}

/// Invert [`Cell::key`].  The rebuilt cell must re-render to the input
/// byte-for-byte (and therefore hash identically).
pub fn parse_cell_key(key: &str) -> Result<Cell> {
    let mut f = Fields::parse(key)?;
    let cell = parse_cell_fields(&mut f)?;
    f.finish()?;
    if cell.key() != key {
        bail!(
            "cell key does not round-trip: '{key}' re-renders as '{}' — \
             refusing to replay a possibly different cell",
            cell.key()
        );
    }
    Ok(cell)
}

fn parse_fault_model(raw: &str) -> Result<FaultModel> {
    if raw == "platform" {
        return Ok(FaultModel::PlatformRenewal);
    }
    if let Some(n) = raw.strip_prefix("perproc") {
        return Ok(FaultModel::PerProcessor { n: num("fault-model procs", n)? });
    }
    if let Some(n) = raw.strip_prefix("stationary") {
        return Ok(FaultModel::PerProcessorStationary { n: num("fault-model procs", n)? });
    }
    bail!("bad fault-model label '{raw}' (platform|perprocN|stationaryN)")
}

/// Invert [`ValCell::key`] (a cell key plus `;fm=…;m=…`), with the same
/// byte-for-byte round-trip requirement.
pub fn parse_val_cell_key(key: &str) -> Result<ValCell> {
    let mut f = Fields::parse(key)?;
    let cell = parse_cell_fields(&mut f)?;
    let fm = parse_fault_model(f.expect("fm")?)?;
    let multiplier: f64 = num("multiplier", f.expect("m")?)?;
    f.finish()?;
    let vc = ValCell::new(cell, multiplier, fm);
    if vc.key() != key {
        bail!(
            "conformance cell key does not round-trip: '{key}' re-renders as '{}'",
            vc.key()
        );
    }
    Ok(vc)
}

/// One diverging field between a stored record and its re-run.
#[derive(Clone, Debug)]
pub struct FieldDiff {
    pub field: &'static str,
    pub stored: String,
    pub fresh: String,
}

fn push_f64(out: &mut Vec<FieldDiff>, field: &'static str, stored: f64, fresh: f64) {
    // Bit-equality, except NaN == NaN (conformance stores null out
    // non-finite fields; they read back as NaN).
    if stored.to_bits() != fresh.to_bits() && !(stored.is_nan() && fresh.is_nan()) {
        out.push(FieldDiff { field, stored: format!("{stored:?}"), fresh: format!("{fresh:?}") });
    }
}

fn push_str(out: &mut Vec<FieldDiff>, field: &'static str, stored: &str, fresh: &str) {
    if stored != fresh {
        out.push(FieldDiff { field, stored: stored.to_string(), fresh: fresh.to_string() });
    }
}

/// Field-for-field diff of two campaign records (empty ⇒ bit-identical
/// replay).
pub fn diff_campaign(stored: &CellRecord, fresh: &CellRecord) -> Vec<FieldDiff> {
    let mut out = Vec::new();
    push_str(&mut out, "key", &stored.key, &fresh.key);
    if stored.hash != fresh.hash {
        out.push(FieldDiff {
            field: "hash",
            stored: format!("{:016x}", stored.hash),
            fresh: format!("{:016x}", fresh.hash),
        });
    }
    if stored.instances != fresh.instances {
        out.push(FieldDiff {
            field: "instances",
            stored: stored.instances.to_string(),
            fresh: fresh.instances.to_string(),
        });
    }
    push_f64(&mut out, "waste_mean", stored.waste_mean, fresh.waste_mean);
    push_f64(&mut out, "waste_var", stored.waste_var, fresh.waste_var);
    push_f64(&mut out, "waste_ci95", stored.waste_ci95, fresh.waste_ci95);
    push_f64(&mut out, "waste_min", stored.waste_min, fresh.waste_min);
    push_f64(&mut out, "waste_max", stored.waste_max, fresh.waste_max);
    push_f64(&mut out, "makespan_mean", stored.makespan_mean, fresh.makespan_mean);
    push_f64(&mut out, "tr", stored.tr, fresh.tr);
    out
}

/// Field-for-field diff of two conformance records.
pub fn diff_conformance(stored: &ConformanceRecord, fresh: &ConformanceRecord) -> Vec<FieldDiff> {
    let mut out = Vec::new();
    push_str(&mut out, "key", &stored.key, &fresh.key);
    if stored.hash != fresh.hash {
        out.push(FieldDiff {
            field: "hash",
            stored: format!("{:016x}", stored.hash),
            fresh: format!("{:016x}", fresh.hash),
        });
    }
    push_str(&mut out, "strategy", &stored.strategy, &fresh.strategy);
    push_str(&mut out, "law", &stored.law, &fresh.law);
    push_f64(&mut out, "multiplier", stored.multiplier, fresh.multiplier);
    push_f64(&mut out, "tr", stored.tr, fresh.tr);
    if stored.instances != fresh.instances {
        out.push(FieldDiff {
            field: "instances",
            stored: stored.instances.to_string(),
            fresh: fresh.instances.to_string(),
        });
    }
    push_f64(&mut out, "sim_mean", stored.sim_mean, fresh.sim_mean);
    push_f64(&mut out, "sim_ci95", stored.sim_ci95, fresh.sim_ci95);
    push_f64(&mut out, "model", stored.model, fresh.model);
    push_f64(&mut out, "deviation", stored.deviation, fresh.deviation);
    push_f64(&mut out, "tolerance", stored.tolerance, fresh.tolerance);
    push_str(&mut out, "verdict", &stored.verdict, &fresh.verdict);
    push_str(&mut out, "reason", &stored.reason, &fresh.reason);
    out
}

/// Re-run a stored campaign cell from its key at its stored instance
/// count and return the fresh record.
pub fn replay_campaign(stored: &CellRecord) -> Result<CellRecord> {
    let cell = parse_cell_key(&stored.key)?;
    if cell.hash != stored.hash {
        bail!(
            "stored hash {:016x} does not match key '{}' (hashes to {:016x}) — corrupt record?",
            stored.hash,
            stored.key,
            cell.hash
        );
    }
    let opt = campaign::CampaignOptions {
        instances: stored.instances.max(1) as usize,
        block: 0,
        threads: 0,
    };
    let (outcomes, _skipped) = campaign::run_cells(&[cell], &opt, None)?;
    outcomes
        .into_iter()
        .next()
        .map(|o| o.record())
        .ok_or_else(|| anyhow!("replay produced no record for {}", stored.key))
}

/// Re-run a stored conformance cell from its key at its stored instance
/// count and return the fresh record.
pub fn replay_conformance(stored: &ConformanceRecord) -> Result<ConformanceRecord> {
    let vc = parse_val_cell_key(&stored.key)?;
    if vc.hash != stored.hash {
        bail!(
            "stored hash {:016x} does not match key '{}' (hashes to {:016x}) — corrupt record?",
            stored.hash,
            stored.key,
            vc.hash
        );
    }
    let opt = SweepOptions {
        instances: stored.instances.max(1) as usize,
        ..SweepOptions::default()
    };
    let (reports, _skipped) = validate::run_sweep(&[vc], &opt, None)?;
    reports
        .into_iter()
        .next()
        .map(|r| r.record())
        .ok_or_else(|| anyhow!("replay produced no record for {}", stored.key))
}

/// Round-trip sanity for the key parsers over a whole grid (used by
/// tests; cheap — no simulation).
pub fn check_grid_round_trip(grid: &Grid) -> Result<()> {
    for cell in grid.expand() {
        let parsed = parse_cell_key(&cell.key())?;
        if parsed.hash != cell.hash {
            bail!("hash drift for '{}'", cell.key());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::registry as predictors;
    use crate::strategy::registry as strategies;

    fn paper_cell(strategy: &str) -> Cell {
        Cell::new(
            1 << 16,
            1.0,
            Law::Exponential,
            Law::Exponential,
            predictors::get("a").unwrap().spec(600.0),
            strategies::get(strategy).unwrap(),
            1.0,
        )
    }

    #[test]
    fn smoke_grid_keys_round_trip() {
        check_grid_round_trip(&Grid::smoke()).unwrap();
    }

    #[test]
    fn exotic_keys_round_trip() {
        // Non-paper predictor models, params, shards, fault models.
        let mut grid = Grid::smoke();
        crate::campaign::overrides::apply_override(
            &mut grid,
            "predictors",
            "a,biased(beta=2),mixedwin,jitter,classed",
        )
        .unwrap();
        crate::campaign::overrides::apply_override(
            &mut grid,
            "strategies",
            "Daly,QTrust(q=0.25),BestPeriod-NoPred(seeds=3)",
        )
        .unwrap();
        crate::campaign::overrides::apply_override(&mut grid, "shards", "1,4").unwrap();
        check_grid_round_trip(&grid).unwrap();
        for cell in grid.expand() {
            for (m, fm) in [
                (1.0, FaultModel::PlatformRenewal),
                (0.75, FaultModel::PerProcessor { n: 1 << 16 }),
                (1.5, FaultModel::PerProcessorStationary { n: 1 << 16 }),
            ] {
                let vc = ValCell::new(cell.clone(), m, fm);
                let parsed = parse_val_cell_key(&vc.key()).unwrap();
                assert_eq!(parsed.hash, vc.hash, "{}", vc.key());
                assert_eq!(parsed.pool_hash, vc.pool_hash, "{}", vc.key());
            }
        }
    }

    #[test]
    fn tampered_keys_are_rejected() {
        let cell = paper_cell("Daly");
        let key = cell.key();
        assert!(parse_cell_key(&key.replace("strat=Daly", "strat=Dailly")).is_err());
        assert!(parse_cell_key(&key.replace("procs=", "procz=")).is_err());
        assert!(parse_cell_key(&format!("{key};extra=1")).is_err());
        assert!(parse_cell_key("procs=10").is_err());
        // Non-canonical float spelling must not silently re-key.
        let err = parse_cell_key(&key.replace("cp=1", "cp=1.0")).unwrap_err();
        assert!(err.to_string().contains("round-trip"), "{err}");
    }

    #[test]
    fn pred_model_labels_round_trip() {
        for model in [
            PredModel::Paper,
            PredModel::Biased { beta: 2.0 },
            PredModel::MixedWindow { i1: 300.0, i2: 1200.0, w: 0.5 },
            PredModel::Jitter { sigma: 120.0 },
            PredModel::Classed { p_hi: 0.95, p_lo: 0.6, frac: 0.5 },
        ] {
            assert_eq!(PredModel::parse_label(&model.label()).unwrap(), model);
        }
        assert!(PredModel::parse_label("nope(beta=1)").is_err());
        assert!(PredModel::parse_label("biased(beta=x)").is_err());
    }
}
