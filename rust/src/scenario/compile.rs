//! Schema layer: lower a parsed [`ScenarioFile`] to grid/conformance
//! cells.
//!
//! Sections:
//!
//! ```text
//! [suite]                        # required
//! name = fig5                    # required: suite id (free text)
//! kind = campaign                # campaign (default) | conformance
//! base = paper                   # campaign: paper (default) | smoke
//!                                # conformance: default (default) | smoke
//!
//! [axes]                         # optional; keys = overrides::AXIS_KEYS
//! predictors = b                 # values use the exact CLI flag syntax
//! cp-ratios = 1.0
//!
//! [sweep]                        # conformance only
//! multipliers = 0.75, 1.0, 1.5   # default: 1.0 (smoke base) or
//!                                # validate::DEFAULT_MULTIPLIERS
//!
//! [expect]                       # optional compile-time assertions
//! cells = 300
//! ```
//!
//! Every `[axes]` entry goes through
//! [`overrides::apply_override`](crate::campaign::overrides::apply_override)
//! on top of the `base` preset — the same call path as the CLI flags —
//! so the compiled grid is byte-identical (keys and hashes) to the
//! equivalent `ckptwin campaign/validate` invocation by construction.

use super::ast::{ScenarioFile, Section};
use super::ScenarioError;
use crate::campaign::{overrides, Cell, Grid};
use crate::util::split_top_level;
use crate::validate::{self, ValCell};

/// What the compiled grid feeds: a waste campaign or a model-vs-sim
/// conformance sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SuiteKind {
    Campaign,
    Conformance,
}

impl SuiteKind {
    pub fn label(self) -> &'static str {
        match self {
            SuiteKind::Campaign => "campaign",
            SuiteKind::Conformance => "conformance",
        }
    }
}

/// A fully resolved suite: registry ids looked up, ranges checked,
/// expectations verified.
#[derive(Clone, Debug)]
pub struct CompiledSuite {
    pub name: String,
    pub kind: SuiteKind,
    /// Base preset the `[axes]` overrides were applied on top of.
    pub base: String,
    pub grid: Grid,
    /// Period multipliers (conformance suites; `[1.0]`-equivalent unused
    /// for campaigns).
    pub multipliers: Vec<f64>,
    pub expect_cells: Option<usize>,
}

impl CompiledSuite {
    /// Total cell count: grid cells × multipliers for conformance
    /// suites, grid cells for campaigns.
    pub fn cell_count(&self) -> usize {
        match self.kind {
            SuiteKind::Campaign => self.grid.len(),
            SuiteKind::Conformance => self.grid.len() * self.multipliers.len(),
        }
    }

    /// Campaign cells in canonical grid-expansion order.
    pub fn cells(&self) -> Vec<Cell> {
        self.grid.expand()
    }

    /// Conformance cells (grid order, multipliers innermost).
    pub fn val_cells(&self) -> Vec<ValCell> {
        validate::expand_cells(&self.grid, &self.multipliers)
    }
}

/// Known section names, for diagnostics.
pub const SECTIONS: &[&str] = &["suite", "axes", "sweep", "expect"];

/// Allowed keys per section (`[axes]` takes
/// [`overrides::AXIS_KEYS`]).
pub fn section_keys(section: &str) -> Option<&'static [&'static str]> {
    match section {
        "suite" => Some(&["name", "kind", "base"]),
        "axes" => Some(overrides::AXIS_KEYS),
        "sweep" => Some(&["multipliers"]),
        "expect" => Some(&["cells"]),
        _ => None,
    }
}

fn unknown_section_err(section: &Section) -> ScenarioError {
    let msg = match overrides::nearest(&section.name, SECTIONS.iter().copied()) {
        Some(s) => format!("unknown section '[{}]' (did you mean '[{s}]'?)", section.name),
        None => format!("unknown section '[{}]'", section.name),
    };
    ScenarioError::new(section.line, msg)
}

fn check_section_keys(section: &Section) -> Result<(), ScenarioError> {
    let allowed = section_keys(&section.name).ok_or_else(|| unknown_section_err(section))?;
    for entry in &section.entries {
        if !allowed.contains(&entry.key.as_str()) {
            let msg = match overrides::nearest(&entry.key, allowed.iter().copied()) {
                Some(s) => format!(
                    "unknown key '{}' in [{}] (did you mean '{s}'?)",
                    entry.key, section.name
                ),
                None => format!("unknown key '{}' in [{}]", entry.key, section.name),
            };
            return Err(ScenarioError::new(entry.line, msg));
        }
    }
    Ok(())
}

fn base_grid(kind: SuiteKind, base: &str) -> Option<Grid> {
    match (kind, base) {
        (SuiteKind::Campaign, "paper") => Some(Grid::paper()),
        (SuiteKind::Campaign, "smoke") => Some(Grid::smoke()),
        (SuiteKind::Conformance, "default") => Some(validate::default_grid()),
        (SuiteKind::Conformance, "smoke") => Some(validate::smoke_grid()),
        _ => None,
    }
}

/// Parse a `[sweep] multipliers` list exactly like `ckptwin validate
/// --multipliers`: finite, positive, bit-deduplicated, order-preserving.
fn parse_multipliers(raw: &str, line: usize) -> Result<Vec<f64>, ScenarioError> {
    let mut out: Vec<f64> = Vec::new();
    for piece in split_top_level(raw) {
        let piece = piece.trim();
        if piece.is_empty() {
            continue;
        }
        let m: f64 = piece
            .parse()
            .map_err(|_| ScenarioError::new(line, format!("bad multiplier '{piece}'")))?;
        if !m.is_finite() || m <= 0.0 {
            return Err(ScenarioError::new(
                line,
                format!("multiplier must be finite and > 0, got '{piece}'"),
            ));
        }
        if !out.iter().any(|x| x.to_bits() == m.to_bits()) {
            out.push(m);
        }
    }
    if out.is_empty() {
        return Err(ScenarioError::new(line, "empty multipliers list"));
    }
    Ok(out)
}

/// Compile a parsed file. Stops at the first error (use
/// [`super::lint`] to collect them all).
pub fn compile(file: &ScenarioFile) -> Result<CompiledSuite, ScenarioError> {
    for section in &file.sections {
        check_section_keys(section)?;
    }
    let suite = file
        .section("suite")
        .ok_or_else(|| ScenarioError::new(0, "missing required [suite] section"))?;
    let name = suite
        .get("name")
        .ok_or_else(|| ScenarioError::new(suite.line, "[suite] is missing 'name'"))?
        .value
        .clone();
    let kind = match suite.get("kind") {
        None => SuiteKind::Campaign,
        Some(e) => match e.value.to_ascii_lowercase().as_str() {
            "campaign" => SuiteKind::Campaign,
            "conformance" => SuiteKind::Conformance,
            other => {
                return Err(ScenarioError::new(
                    e.line,
                    format!("unknown kind '{other}' (campaign|conformance)"),
                ))
            }
        },
    };
    let default_base = match kind {
        SuiteKind::Campaign => "paper",
        SuiteKind::Conformance => "default",
    };
    let (base, base_line) = match suite.get("base") {
        Some(e) => (e.value.to_ascii_lowercase(), e.line),
        None => (default_base.to_string(), suite.line),
    };
    let mut grid = base_grid(kind, &base).ok_or_else(|| {
        let known = match kind {
            SuiteKind::Campaign => "paper|smoke",
            SuiteKind::Conformance => "default|smoke",
        };
        ScenarioError::new(
            base_line,
            format!("unknown base '{base}' for a {} suite ({known})", kind.label()),
        )
    })?;

    if let Some(axes) = file.section("axes") {
        for entry in &axes.entries {
            overrides::apply_override(&mut grid, &entry.key, &entry.value)
                .map_err(|msg| ScenarioError::new(entry.line, msg))?;
        }
    }
    if grid.is_empty() {
        return Err(ScenarioError::new(0, "grid has an empty axis — nothing to run"));
    }

    let multipliers = match (kind, file.section("sweep")) {
        (SuiteKind::Campaign, Some(s)) => {
            return Err(ScenarioError::new(
                s.line,
                "[sweep] only applies to conformance suites (set kind = conformance)",
            ));
        }
        (SuiteKind::Campaign, None) => vec![1.0],
        (SuiteKind::Conformance, sweep) => match sweep.and_then(|s| s.get("multipliers")) {
            Some(e) => parse_multipliers(&e.value, e.line)?,
            None => {
                if base == "smoke" {
                    vec![1.0]
                } else {
                    validate::DEFAULT_MULTIPLIERS.to_vec()
                }
            }
        },
    };

    let expect_cells = match file.section("expect").and_then(|s| s.get("cells")) {
        Some(e) => Some(e.value.trim().parse::<usize>().map_err(|_| {
            ScenarioError::new(e.line, format!("bad cell count '{}'", e.value))
        })?),
        None => None,
    };

    let compiled =
        CompiledSuite { name, kind, base, grid, multipliers, expect_cells };
    if let Some(expected) = compiled.expect_cells {
        let got = compiled.cell_count();
        if got != expected {
            let line = file
                .section("expect")
                .and_then(|s| s.get("cells"))
                .map(|e| e.line)
                .unwrap_or(0);
            return Err(ScenarioError::new(
                line,
                format!("expectation failed: [expect] cells = {expected}, grid compiles to {got}"),
            ));
        }
    }
    Ok(compiled)
}

/// Parse + compile in one step.
pub fn compile_str(text: &str) -> Result<CompiledSuite, ScenarioError> {
    compile(&ScenarioFile::parse(text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimal_campaign_suite_defaults_to_paper() {
        let s = compile_str("[suite]\nname = t\n").unwrap();
        assert_eq!(s.kind, SuiteKind::Campaign);
        assert_eq!(s.base, "paper");
        assert_eq!(s.grid.len(), Grid::paper().len());
        assert_eq!(s.cell_count(), 1200);
    }

    #[test]
    fn axes_override_base_preset() {
        let s = compile_str(
            "[suite]\nname = t\nbase = smoke\n\n[axes]\nstrategies = RFO\nwindows = 600\n",
        )
        .unwrap();
        assert_eq!(s.grid.strategies.len(), 1);
        assert_eq!(s.grid.windows, vec![600.0]);
        assert_eq!(s.cell_count(), 4);
    }

    #[test]
    fn conformance_suite_defaults_and_sweep() {
        let s = compile_str("[suite]\nname = t\nkind = conformance\nbase = smoke\n").unwrap();
        assert_eq!(s.multipliers, vec![1.0]);
        assert_eq!(s.cell_count(), 72);
        let s = compile_str(
            "[suite]\nname = t\nkind = conformance\nbase = smoke\n\n[sweep]\nmultipliers = 0.75, 1.0, 0.75\n",
        )
        .unwrap();
        assert_eq!(s.multipliers, vec![0.75, 1.0]);
    }

    #[test]
    fn conformance_default_base_gets_default_multipliers() {
        let s = compile_str("[suite]\nname = t\nkind = conformance\n").unwrap();
        assert_eq!(s.base, "default");
        assert_eq!(s.multipliers, validate::DEFAULT_MULTIPLIERS.to_vec());
    }

    #[test]
    fn diagnostics_carry_lines_and_suggestions() {
        let e = compile_str("[suite]\nname = t\n\n[axis]\nprocs = 1\n").unwrap_err();
        assert_eq!(e.line, 4);
        assert!(e.msg.contains("did you mean '[axes]'"), "{e}");

        let e = compile_str("[suite]\nname = t\n\n[axes]\nprocz = 1\n").unwrap_err();
        assert_eq!(e.line, 5);
        assert!(e.msg.contains("did you mean 'procs'"), "{e}");

        let e = compile_str("[suite]\nname = t\n\n[axes]\nstrategies = dailly\n").unwrap_err();
        assert_eq!(e.line, 5);
        assert!(e.to_string().to_ascii_lowercase().contains("did you mean"), "{e}");

        let e = compile_str("[suite]\nname = t\n\n[sweep]\nmultipliers = 1\n").unwrap_err();
        assert_eq!(e.line, 4);
        assert!(e.msg.contains("conformance"), "{e}");

        let e = compile_str("[suite]\nname = t\nbase = smoke\n\n[expect]\ncells = 17\n")
            .unwrap_err();
        assert_eq!(e.line, 6);
        assert!(e.msg.contains("compiles to 16"), "{e}");
    }

    #[test]
    fn missing_suite_or_name_is_an_error() {
        assert!(compile_str("[axes]\nprocs = 1\n").unwrap_err().msg.contains("[suite]"));
        assert!(compile_str("[suite]\nkind = campaign\n").unwrap_err().msg.contains("name"));
    }
}
