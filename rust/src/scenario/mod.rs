//! # Scenario language — declarative `.ckpt` suites
//!
//! A `.ckpt` file names strategies, predictors, fault laws, platform
//! sizes and prediction windows by their registry ids and compiles to
//! the exact same [`campaign::Grid`](crate::campaign::Grid) /
//! [`validate::ValCell`](crate::validate::ValCell) cells the CLI flags
//! produce — byte-identical store keys and scenario hashes, because the
//! compiler funnels every `[axes]` entry through
//! [`campaign::overrides::apply_override`](crate::campaign::overrides::apply_override),
//! the same function that backs `--procs`/`--strategies`/… (pinned by
//! `tests/scenario.rs`).
//!
//! Pipeline: text → [`ast::ScenarioFile`] (syntax + line numbers) →
//! [`compile::CompiledSuite`] (registry resolution, range checks,
//! expectation checks) → cells. [`lint`] runs the same pipeline but
//! collects *all* diagnostics and adds a validity-domain pre-pass;
//! [`replay`] inverts the store-key grammar so any stored cell can be
//! re-run bit-identically from its hash; [`explain`] prints why a
//! conformance cell passed/failed/was classified, with the 5-term
//! priced tolerance broken out. See `DESIGN.md` §Scenario language.

pub mod ast;
pub mod compile;
pub mod explain;
pub mod lint;
pub mod replay;

pub use ast::ScenarioFile;
pub use compile::{CompiledSuite, SuiteKind};
pub use explain::{explain_cell, Explanation};
pub use lint::{lint_str, LintReport};

use std::fmt;

/// A scenario-language diagnostic carrying the 1-based source line it
/// points at (`line == 0` means the error is file-level, e.g. a missing
/// required section).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ScenarioError {
    pub line: usize,
    pub msg: String,
}

impl ScenarioError {
    pub fn new(line: usize, msg: impl Into<String>) -> Self {
        ScenarioError { line, msg: msg.into() }
    }
}

impl fmt::Display for ScenarioError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.line > 0 {
            write!(f, "line {}: {}", self.line, self.msg)
        } else {
            f.write_str(&self.msg)
        }
    }
}

impl std::error::Error for ScenarioError {}
