//! Syntax layer of the `.ckpt` scenario language.
//!
//! The grammar is a deliberately tiny TOML-flavored subset, line-oriented
//! so every diagnostic carries an exact line number:
//!
//! ```text
//! # whole-line comments and blank lines are ignored
//! [section]
//! key = value            # value runs to end of line (no trailing comments)
//! other-key = "quoted"   # surrounding double quotes are stripped
//! ```
//!
//! The parser checks *syntax only* — unknown sections/keys are accepted
//! here and rejected by `compile`/`lint`, which know the schema. It does
//! reject structural duplicates (two `[axes]` sections, the same key
//! twice in one section) because those are ambiguous no matter the
//! schema.
//!
//! [`ScenarioFile::render`] emits the canonical form; `parse ∘ render`
//! is a fixpoint (pinned by `tests/scenario.rs`), which is what makes
//! committed `.ckpt` files diffable artifacts.

use super::ScenarioError;

/// One `key = value` entry with its source line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Entry {
    pub key: String,
    pub value: String,
    pub line: usize,
}

/// One `[name]` section and its entries, in file order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Section {
    pub name: String,
    pub line: usize,
    pub entries: Vec<Entry>,
}

impl Section {
    pub fn get(&self, key: &str) -> Option<&Entry> {
        self.entries.iter().find(|e| e.key == key)
    }
}

/// A parsed `.ckpt` file: sections in file order.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ScenarioFile {
    pub sections: Vec<Section>,
}

impl ScenarioFile {
    pub fn section(&self, name: &str) -> Option<&Section> {
        self.sections.iter().find(|s| s.name == name)
    }

    /// Parse scenario text. Errors carry 1-based line numbers.
    pub fn parse(text: &str) -> Result<ScenarioFile, ScenarioError> {
        let mut file = ScenarioFile::default();
        for (idx, raw) in text.lines().enumerate() {
            let line = idx + 1;
            let trimmed = raw.trim();
            if trimmed.is_empty() || trimmed.starts_with('#') {
                continue;
            }
            if let Some(rest) = trimmed.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| {
                    ScenarioError::new(line, format!("unterminated section header '{trimmed}'"))
                })?;
                let name = name.trim();
                if name.is_empty() {
                    return Err(ScenarioError::new(line, "empty section name '[]'"));
                }
                if let Some(prev) = file.section(name) {
                    return Err(ScenarioError::new(
                        line,
                        format!("duplicate section '[{name}]' (first defined at line {})", prev.line),
                    ));
                }
                file.sections.push(Section { name: name.to_string(), line, entries: Vec::new() });
                continue;
            }
            let (key, value) = trimmed.split_once('=').ok_or_else(|| {
                ScenarioError::new(line, format!("expected 'key = value' or '[section]', got '{trimmed}'"))
            })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(ScenarioError::new(line, "empty key before '='"));
            }
            let mut value = value.trim();
            if value.len() >= 2 && value.starts_with('"') && value.ends_with('"') {
                value = &value[1..value.len() - 1];
            }
            let section = file.sections.last_mut().ok_or_else(|| {
                ScenarioError::new(line, format!("entry '{key}' appears before any [section]"))
            })?;
            if let Some(prev) = section.entries.iter().find(|e| e.key == key) {
                return Err(ScenarioError::new(
                    line,
                    format!(
                        "duplicate key '{key}' in [{}] (first set at line {})",
                        section.name, prev.line
                    ),
                ));
            }
            section.entries.push(Entry { key: key.to_string(), value: value.to_string(), line });
        }
        Ok(file)
    }

    /// Canonical rendering: one section per block, `key = value` lines,
    /// blank line between sections. `parse(render(f))` reproduces `f`
    /// up to line numbers, and `render` is idempotent on its own output.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (i, section) in self.sections.iter().enumerate() {
            if i > 0 {
                out.push('\n');
            }
            out.push('[');
            out.push_str(&section.name);
            out.push_str("]\n");
            for entry in &section.entries {
                out.push_str(&entry.key);
                out.push_str(" = ");
                out.push_str(&entry.value);
                out.push('\n');
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_entries_and_comments() {
        let f = ScenarioFile::parse(
            "# header comment\n\n[suite]\nname = demo\n\n[axes]\nprocs = 1024, 2048\n",
        )
        .unwrap();
        assert_eq!(f.sections.len(), 2);
        assert_eq!(f.section("suite").unwrap().get("name").unwrap().value, "demo");
        let procs = f.section("axes").unwrap().get("procs").unwrap();
        assert_eq!(procs.value, "1024, 2048");
        assert_eq!(procs.line, 7);
    }

    #[test]
    fn quoted_values_are_stripped() {
        let f = ScenarioFile::parse("[suite]\nname = \"paper fig 5\"\n").unwrap();
        assert_eq!(f.section("suite").unwrap().get("name").unwrap().value, "paper fig 5");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = ScenarioFile::parse("[suite]\nname = a\n[suite]\n").unwrap_err();
        assert_eq!(e.line, 3);
        assert!(e.msg.contains("duplicate section"), "{e}");
        assert!(e.msg.contains("line 1"), "{e}");

        let e = ScenarioFile::parse("[axes]\nprocs = 1\nprocs = 2\n").unwrap_err();
        assert_eq!(e.line, 3);
        assert!(e.msg.contains("duplicate key 'procs'"), "{e}");

        let e = ScenarioFile::parse("name = orphan\n").unwrap_err();
        assert_eq!(e.line, 1);
        assert!(e.msg.contains("before any [section]"), "{e}");

        let e = ScenarioFile::parse("[oops\n").unwrap_err();
        assert_eq!(e.line, 1);
        assert!(e.msg.contains("unterminated"), "{e}");

        let e = ScenarioFile::parse("[axes]\njust words\n").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.to_string().starts_with("line 2: "), "{e}");
    }

    #[test]
    fn render_parse_fixpoint() {
        let src = "[suite]\nname = demo\nkind = campaign\n\n[axes]\nprocs = 1024\n";
        let f = ScenarioFile::parse(src).unwrap();
        let rendered = f.render();
        assert_eq!(rendered, src);
        let f2 = ScenarioFile::parse(&rendered).unwrap();
        assert_eq!(f, f2);
    }
}
