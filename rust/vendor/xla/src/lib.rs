//! Vendored stub of the `xla` PJRT bindings (offline environment: no
//! crates.io, no libxla).  Mirrors exactly the API surface
//! `ckptwin::runtime` compiles against; every entry point that would need
//! the real PJRT runtime returns [`Error`] at run time, starting with
//! [`PjRtClient::cpu`] — so `Runtime::discover()` fails gracefully and the
//! rest of the system (simulator, analytic model, campaign engine) is
//! unaffected.  Linking the real bindings back in only requires swapping
//! this path dependency for the upstream crate.

use std::fmt;

/// Error raised by every stubbed PJRT operation.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime unavailable (built against the vendored xla \
         stub; link the real xla crate to enable artifact execution)"
    ))
}

/// Host-side tensor handle (stub carries no data).
#[derive(Clone, Debug, Default)]
pub struct Literal;

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(unavailable("Literal::to_tuple"))
    }
}

impl From<u32> for Literal {
    fn from(_v: u32) -> Literal {
        Literal
    }
}

impl From<f32> for Literal {
    fn from(_v: f32) -> Literal {
        Literal
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by an execution (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled, loaded executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client (stub — construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_stub() {
        let err = PjRtClient::cpu().map(|_| ()).unwrap_err();
        assert!(format!("{err:?}").contains("stub"));
    }
}
