//! Vendored drop-in subset of the `anyhow` crate (offline environment: no
//! crates.io).  Implements exactly the surface `ckptwin` uses: the
//! [`Error`] type, the [`Result`] alias, the [`anyhow!`] macro, and the
//! [`Context`] extension trait.  Errors are rendered eagerly into a
//! message string; `{:#}` prints the same chain as `{}` (contexts are
//! folded in `context: cause` order, like upstream's alternate format).

use std::fmt;

/// A string-backed error type, convertible from any `std::error::Error`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like upstream anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coherent (it would otherwise collide with `impl From<T> for T`).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error branch of a `Result`.
pub trait Context<T, E> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::Other, "disk on fire");
        Err(e)?;
        Ok(())
    }

    #[test]
    fn macro_and_from_conversion() {
        let e = anyhow!("bad value: {}", 42);
        assert_eq!(e.to_string(), "bad value: 42");
        let e = io_fail().unwrap_err();
        assert_eq!(e.to_string(), "disk on fire");
    }

    #[test]
    fn context_chains() {
        let e: Result<()> = io_fail().context("loading manifest");
        assert_eq!(e.unwrap_err().to_string(), "loading manifest: disk on fire");
        let e: Result<()> = io_fail().with_context(|| format!("step {}", 3));
        assert_eq!(e.unwrap_err().to_string(), "step 3: disk on fire");
    }
}
