//! Property-based tests (offline environment: no proptest — a small
//! seeded-case runner lives here).  Each property is checked over many
//! randomly generated configurations; failures print the offending case.

use ckptwin::config::{FaultModel, Platform, PredictorSpec, Scenario};
use ckptwin::model::{optimal, waste};
use ckptwin::sim::distribution::Law;
use ckptwin::sim::rng::Rng;
use ckptwin::sim::trace::{Event, TraceStream};
use ckptwin::strategy::{Policy, PolicyKind};

/// Run `prop` over `n` random cases derived from `seed`.
fn for_cases<F: FnMut(u64, &mut Rng)>(seed: u64, n: usize, mut prop: F) {
    for case in 0..n {
        let mut rng = Rng::stream(seed, case as u64);
        prop(case as u64, &mut rng);
    }
}

/// Draw a random but *sane* scenario (the paper's parameter envelope,
/// slightly widened).
fn arb_scenario(rng: &mut Rng) -> Scenario {
    let c = rng.range(60.0, 1200.0);
    let mu = rng.range(30.0 * c, 800.0 * c);
    let cp = c * [0.1, 0.5, 1.0, 2.0][rng.below(4)];
    let window = rng.range(60.0, 3600.0);
    let law = [
        Law::Exponential,
        Law::Weibull { shape: 0.7 },
        Law::Weibull { shape: 0.5 },
    ][rng.below(3)];
    let fp_law = if rng.bernoulli(0.3) { Law::Uniform } else { law };
    Scenario {
        platform: Platform {
            mu,
            c,
            cp,
            d: rng.range(0.0, 120.0),
            r: rng.range(60.0, 1200.0),
        },
        predictor: PredictorSpec::paper(
            rng.range(0.05, 0.99),
            rng.range(0.05, 0.99),
            window,
        ),
        fault_law: law,
        false_pred_law: fp_law,
        fault_model: FaultModel::PlatformRenewal,
        job_size: rng.range(20.0 * mu, 60.0 * mu).max(100.0 * c),
    }
}

fn arb_policy(sc: &Scenario, rng: &mut Rng) -> Policy {
    // All seven execution modes, including the registry extensions — the
    // conservation/determinism/accounting properties are mode-generic.
    let kind = match rng.below(7) {
        0 => PolicyKind::IgnorePredictions,
        1 => PolicyKind::Instant,
        2 => PolicyKind::NoCkpt,
        3 => PolicyKind::WithCkpt,
        4 => PolicyKind::ExactPred,
        5 => PolicyKind::WindowEndCkpt,
        _ => PolicyKind::QTrust { q: rng.range(0.05, 0.95) },
    };
    let tr = rng.range(1.05 * sc.platform.c, 50.0 * sc.platform.c);
    let tp = rng.range(1.05 * sc.platform.cp, 4.0 * sc.platform.cp + 100.0);
    Policy { kind, tr, tp }
}

/// Work conservation: makespan is fully decomposed by the outcome buckets,
/// the waste lies in [0,1), and the makespan is at least the job size.
#[test]
fn prop_engine_conservation_and_bounds() {
    for_cases(11, 60, |case, rng| {
        let sc = arb_scenario(rng);
        let pol = arb_policy(&sc, rng);
        let out = ckptwin::simulate(&sc, &pol, case);
        let accounted = sc.job_size
            + out.time_ckpt
            + out.time_down
            + out.time_idle
            + out.work_lost;
        let rel = (out.makespan - accounted).abs() / out.makespan;
        assert!(
            rel < 1e-9,
            "case {case}: makespan {} != accounted {accounted}\n{sc:?}\n{pol:?}",
            out.makespan
        );
        assert!(out.makespan >= sc.job_size);
        assert!((0.0..1.0).contains(&out.waste()), "case {case}");
    });
}

/// Determinism: identical (scenario, policy, seed) => identical outcome.
#[test]
fn prop_engine_deterministic() {
    for_cases(13, 30, |case, rng| {
        let sc = arb_scenario(rng);
        let pol = arb_policy(&sc, rng);
        let a = ckptwin::simulate(&sc, &pol, case);
        let b = ckptwin::simulate(&sc, &pol, case);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "case {case}");
        assert_eq!(a.n_faults, b.n_faults);
        assert_eq!(a.n_reg_ckpts, b.n_reg_ckpts);
        assert_eq!(a.n_pro_ckpts, b.n_pro_ckpts);
    });
}

/// Checkpoint accounting: completed checkpoint time equals the per-kind
/// counts times the respective durations.
#[test]
fn prop_checkpoint_time_consistent() {
    for_cases(17, 40, |case, rng| {
        let sc = arb_scenario(rng);
        let pol = arb_policy(&sc, rng);
        let out = ckptwin::simulate(&sc, &pol, case);
        let expect = out.n_reg_ckpts as f64 * sc.platform.c
            + out.n_pro_ckpts as f64 * sc.platform.cp;
        assert!(
            (out.time_ckpt - expect).abs() < 1e-6 * expect.max(1.0),
            "case {case}: {} vs {expect}",
            out.time_ckpt
        );
    });
}

/// Trace invariants: visible-time order; every predicted fault covered by a
/// window; prediction lead time is exactly C_p.
#[test]
fn prop_trace_invariants() {
    for_cases(19, 30, |case, rng| {
        let sc = arb_scenario(rng);
        let mut ts = TraceStream::new(&sc, case);
        let horizon = 50.0 * sc.platform.mu;
        let evs = ts.take_until(horizon);
        let mut prev = 0.0;
        let mut windows: Vec<(f64, f64)> = Vec::new();
        for e in &evs {
            assert!(e.time() >= prev, "case {case}: out of order");
            prev = e.time();
            match e {
                Event::Prediction(p) => {
                    // Absolute times can be ~1e8; allow f64 cancellation.
                    let tol = 1e-9 * p.window_start.abs().max(1.0);
                    assert!(
                        (p.window_start - p.notify_t - sc.platform.cp).abs()
                            < tol
                    );
                    assert!(
                        (p.window_end - p.window_start
                            - sc.predictor.window)
                            .abs()
                            < tol
                    );
                    if p.true_positive {
                        windows.push((p.window_start, p.window_end));
                    }
                }
                Event::Fault { t, predicted: true } => {
                    assert!(
                        windows.iter().any(|&(s, e)| *t >= s && *t <= e),
                        "case {case}: predicted fault at {t} uncovered"
                    );
                }
                _ => {}
            }
        }
    });
}

/// The closed-form optimum beats (or ties) every grid point of its own
/// waste function — i.e. the calculus in §3.2–3.4 is right.
#[test]
fn prop_closed_form_minimizes_waste() {
    for_cases(23, 40, |case, rng| {
        let sc = arb_scenario(rng);
        let cases: [(f64, fn(&Scenario, f64) -> f64); 2] = [
            (optimal::tr_extr_instant(&sc), waste::instant),
            (optimal::tr_extr_window(&sc), waste::nockpt),
        ];
        for (tr_opt, f) in cases {
            let w_opt = f(&sc, tr_opt);
            for k in 1..60 {
                let tr = sc.platform.c * (1.05 + k as f64);
                assert!(
                    f(&sc, tr) >= w_opt - 1e-9,
                    "case {case}: tr {tr} beats optimum {tr_opt}"
                );
            }
        }
    });
}

/// `tr_extr` formulas are *local minima* of their own waste curves: a
/// ±ε probe around the returned period never finds a lower waste, over
/// random valid scenarios.  (The grid tests above check global shape at
/// fixed points; this checks the calculus at the stationary point itself,
/// wherever the guards leave it interior.)
#[test]
fn prop_tr_extr_is_a_local_minimum() {
    let mut probed = 0;
    for_cases(41, 80, |case, rng| {
        let sc = arb_scenario(rng);
        let cases: [(f64, fn(&Scenario, f64) -> f64); 2] = [
            (optimal::tr_extr_instant(&sc), waste::instant),
            (optimal::tr_extr_window(&sc), waste::nockpt),
        ];
        for (tr_opt, f) in cases {
            // Only interior optima: at the 1.1C clamp the derivative need
            // not vanish (the guard, not the calculus, chose the point).
            if tr_opt <= 1.1 * sc.platform.c * 1.0001 {
                continue;
            }
            probed += 1;
            let w0 = f(&sc, tr_opt);
            for eps in [1e-2, 1e-3] {
                let lo = f(&sc, tr_opt * (1.0 - eps));
                let hi = f(&sc, tr_opt * (1.0 + eps));
                assert!(
                    lo >= w0 - 1e-10 && hi >= w0 - 1e-10,
                    "case {case}: T* = {tr_opt} not a local min \
                     (f(T*) = {w0}, f(-) = {lo}, f(+) = {hi})\n{sc:?}"
                );
            }
        }
    });
    assert!(probed >= 25, "only {probed} interior optima probed");
}

/// Same for `tp_extr`: a ±ε probe in the proactive period around
/// `T_P^extr` (at fixed `T_R`) never lowers Eq. (4)'s waste, whenever the
/// clamp `[C_p, max(C_p, I)]` leaves the optimum interior.
#[test]
fn prop_tp_extr_is_a_local_minimum() {
    let mut probed = 0;
    for_cases(43, 120, |case, rng| {
        let sc = arb_scenario(rng);
        let tp_opt = optimal::tp_extr(&sc);
        let (cp, i) = (sc.platform.cp, sc.predictor.window);
        if tp_opt <= cp * 1.0001 || tp_opt >= i.max(cp) * 0.9999 {
            return; // clamped: boundary, not stationary point
        }
        probed += 1;
        let tr = optimal::tr_extr_window(&sc);
        let w0 = waste::withckpt(&sc, tr, tp_opt);
        for eps in [1e-2, 1e-3] {
            let lo = waste::withckpt(&sc, tr, tp_opt * (1.0 - eps));
            let hi = waste::withckpt(&sc, tr, tp_opt * (1.0 + eps));
            assert!(
                lo >= w0 - 1e-10 && hi >= w0 - 1e-10,
                "case {case}: T_P* = {tp_opt} not a local min \
                 (f(T_P*) = {w0}, f(-) = {lo}, f(+) = {hi})\n{sc:?}"
            );
        }
    });
    assert!(probed >= 20, "only {probed} interior optima probed");
}

/// Waste is monotone in 1/μ at fixed period (more faults, more waste) for
/// the analytic model.
#[test]
fn prop_waste_monotone_in_fault_rate() {
    for_cases(29, 40, |case, rng| {
        let mut sc = arb_scenario(rng);
        let tr = rng.range(2.0 * sc.platform.c, 40.0 * sc.platform.c);
        let tp = optimal::tp_extr(&sc);
        let mut prev = f64::NEG_INFINITY;
        for mult in [8.0, 4.0, 2.0, 1.0] {
            sc.platform.mu = mult * 100.0 * sc.platform.c;
            let w = waste::withckpt(&sc, tr, tp);
            assert!(w >= prev - 1e-12, "case {case}");
            prev = w;
        }
    });
}

/// BestPeriod search never returns something worse than the closed form
/// (it includes the analytic candidate in its sweep).
#[test]
fn prop_best_period_upper_bounded_by_formula() {
    use ckptwin::strategy::best_period;
    for_cases(31, 8, |case, rng| {
        let mut sc = arb_scenario(rng);
        sc.job_size = sc.job_size.min(30.0 * sc.platform.mu); // keep it fast
        let kind = [PolicyKind::IgnorePredictions, PolicyKind::NoCkpt]
            [rng.below(2)];
        let tp = optimal::tp_extr(&sc).max(sc.platform.cp * 1.05);
        let seeds = [case, case + 1000];
        let tr_formula = match kind {
            PolicyKind::IgnorePredictions => optimal::rfo_period(&sc.platform),
            _ => optimal::tr_extr_window(&sc),
        }
        .min(sc.job_size);
        let w_formula =
            best_period::mean_waste(&sc, kind, tr_formula, tp, &seeds);
        let bp = best_period::search(&sc, kind, tp, &seeds, 16, 6);
        assert!(
            bp.waste <= w_formula + 1e-9,
            "case {case}: search {} vs formula {w_formula}",
            bp.waste
        );
    });
}

/// Sharded aggregation (the campaign / telemetry merge path): a random
/// stream split at random shard boundaries and merged in order agrees
/// with one sequential accumulator to ULP-scale tolerance, for any shard
/// count — the Chan et al. parallel update loses no precision worth
/// caring about.
#[test]
fn prop_welford_shard_merge_matches_sequential() {
    use ckptwin::stats::Welford;
    for_cases(47, 60, |case, rng| {
        let n = 50 + rng.below(500);
        let xs: Vec<f64> = (0..n).map(|_| rng.range(-1e3, 1e3)).collect();
        let whole = Welford::from_iter(xs.iter().copied());
        // 1..=6 shards at random cut points (empty shards allowed).
        let mut cuts: Vec<usize> = (0..rng.below(6)).map(|_| rng.below(n + 1)).collect();
        cuts.push(0);
        cuts.push(n);
        cuts.sort_unstable();
        let mut merged = Welford::new();
        for w in cuts.windows(2) {
            merged.merge(&Welford::from_iter(xs[w[0]..w[1]].iter().copied()));
        }
        assert_eq!(merged.len(), whole.len(), "case {case}");
        let mean_scale = whole.mean().abs().max(1.0);
        assert!(
            (merged.mean() - whole.mean()).abs() <= 1e-12 * mean_scale,
            "case {case}: mean {} vs {}",
            merged.mean(),
            whole.mean()
        );
        assert!(
            (merged.var() - whole.var()).abs() <= 1e-9 * whole.var().max(1e-9),
            "case {case}: var {} vs {}",
            merged.var(),
            whole.var()
        );
        assert_eq!(merged.min(), whole.min(), "case {case}");
        assert_eq!(merged.max(), whole.max(), "case {case}");
    });
}

/// Statistics sanity on real outcomes: CI halves when instances quadruple
/// (approximately — random, so generous tolerance).
#[test]
fn prop_ci_shrinks_with_instances() {
    use ckptwin::harness::run_instances;
    let sc = Scenario::paper(
        1 << 17,
        1.0,
        PredictorSpec::paper_a(600.0),
        Law::Exponential,
        Law::Exponential,
    );
    let pol = ckptwin::strategy::registry::get("RFO").unwrap().policy(&sc);
    let (small, _) = run_instances(&sc, &pol, 8);
    let (large, _) = run_instances(&sc, &pol, 64);
    assert!(large.ci95() < small.ci95() * 1.2);
}

/// The paper's §3.2 claim, verified by simulation: the optimal trust
/// probability is at an extreme — for every scenario, min over q of the
/// mean waste is attained (within noise) at q = 0 or q = 1, never strictly
/// inside (0, 1).
#[test]
fn prop_optimal_trust_probability_is_extreme() {
    use ckptwin::sim::engine::simulate_q;
    for_cases(37, 10, |case, rng| {
        let mut sc = arb_scenario(rng);
        sc.job_size = sc.job_size.min(40.0 * sc.platform.mu);
        let kind = [PolicyKind::Instant, PolicyKind::NoCkpt, PolicyKind::WithCkpt]
            [rng.below(3)];
        let tr = optimal::tr_extr_window(&sc).min(sc.job_size);
        let tp = optimal::tp_extr(&sc).max(sc.platform.cp * 1.05);
        let pol = Policy { kind, tr, tp };
        let seeds: Vec<u64> = (0..12u64).map(|s| s * 31 + case).collect();
        let mean = |q: f64| -> f64 {
            seeds
                .iter()
                .map(|&s| simulate_q(&sc, &pol, q, s).waste())
                .sum::<f64>()
                / seeds.len() as f64
        };
        let extremes = mean(0.0).min(mean(1.0));
        for q in [0.25, 0.5, 0.75] {
            // Interior q can beat an extreme only within paired noise.
            assert!(
                mean(q) >= extremes - 0.02,
                "case {case}: q={q} gives {} vs extremes {extremes}",
                mean(q)
            );
        }
    });
}

/// Torn-tail repair (the crash model behind every JSONL store): truncate
/// the file at a *random* byte offset, reopen, and require that (a) every
/// record whose full line landed before the cut survives, (b) at most the
/// one in-flight line is lost, and (c) the repair is idempotent — further
/// reopens see exactly the same records and skip count.
#[test]
fn prop_jsonl_torn_tail_repair_idempotent_and_lossless() {
    use ckptwin::jsonio::{self, JsonlAppender, RecordCheck, Value};
    use std::collections::BTreeMap;
    use std::path::Path;

    // Count (clean records, skipped lines) via a replaying open.
    fn scan(path: &Path) -> (usize, usize) {
        let mut good = 0;
        let ap = JsonlAppender::open(path, false, |l| match jsonio::parse(l) {
            Ok(v) if jsonio::check_record(&v) == RecordCheck::Clean => {
                good += 1;
                true
            }
            _ => false,
        })
        .unwrap();
        (good, ap.skipped_lines)
    }

    for_cases(0xA11CE, 80, |case, rng| {
        let path = std::env::temp_dir().join(format!(
            "ckptwin-prop-torn-{}-{case}.jsonl",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let n = 3 + rng.below(6);
        let mut lines = Vec::with_capacity(n);
        {
            let mut ap = JsonlAppender::open(&path, true, |_| true).unwrap();
            for i in 0..n {
                let mut obj = BTreeMap::new();
                obj.insert("idx".to_string(), Value::Num(i as f64));
                obj.insert("key".to_string(), Value::Str(format!("r{case}-{i}")));
                let line = jsonio::seal_record(obj);
                ap.append_line(&line).unwrap();
                lines.push(line);
            }
        }
        let full = std::fs::read(&path).unwrap();
        let cut = rng.below(full.len() + 1);
        std::fs::write(&path, &full[..cut]).unwrap();

        // Expected survivors: every line whose `line\n` block is fully
        // inside the cut, plus a final line cut exactly before its
        // newline (complete JSON, only the terminator lost).
        let mut off = 0;
        let mut whole = 0;
        let mut remainder = 0;
        for line in &lines {
            let end = off + line.len();
            if end + 1 <= cut {
                whole += 1;
                off = end + 1;
            } else {
                remainder = cut - off;
                break;
            }
        }
        let tail_survives = whole < n && remainder == lines[whole].len();
        let expect_good = whole + usize::from(tail_survives);
        let expect_skip = usize::from(remainder > 0 && !tail_survives);

        let (good, skipped) = scan(&path);
        assert_eq!(
            (good, skipped),
            (expect_good, expect_skip),
            "case {case}: cut {cut} of {} (lines of {:?})",
            full.len(),
            lines.iter().map(String::len).collect::<Vec<_>>()
        );
        assert!(good >= whole, "a fully-written record was dropped");

        // Idempotence: repair already ran; reopening changes nothing.
        assert_eq!(scan(&path), (expect_good, expect_skip), "case {case}");

        // And the repaired file accepts appends on a fresh line.
        {
            let mut ap = JsonlAppender::open(&path, false, |_| true).unwrap();
            let mut obj = BTreeMap::new();
            obj.insert("idx".to_string(), Value::Num(n as f64));
            obj.insert("key".to_string(), Value::Str("post-repair".into()));
            ap.append_line(&jsonio::seal_record(obj)).unwrap();
        }
        assert_eq!(scan(&path), (expect_good + 1, expect_skip), "case {case}");
        let _ = std::fs::remove_file(&path);
    });
}

/// The key/list grammars lean on paren-aware top-level splitting:
/// `--strategies qtrust(q=0.25,...)` splits on top-level commas, and
/// `scenario::replay` walks store-key fields on top-level `;` (predictor
/// labels like `mixedwin(i1=300;i2=1200;w=0.5)` embed the separator).
/// Over adversarial nested/unbalanced inputs: never panics, always
/// yields at least one piece, re-joining with the separator reproduces
/// the input byte-for-byte, and every piece is itself separator-free at
/// top level (re-splitting a piece is a fixpoint).
#[test]
fn prop_split_top_level_join_identity() {
    use ckptwin::util::{split_top_level, split_top_level_on};
    const CHARS: &[char] =
        &['(', ')', '(', ',', ';', '=', 'a', 'b', '0', '.', ' ', 'µ'];
    for_cases(53, 400, |case, rng| {
        let len = rng.below(25);
        let s: String = (0..len).map(|_| CHARS[rng.below(CHARS.len())]).collect();
        for sep in [',', ';'] {
            let sep_str = sep.to_string();
            let pieces = split_top_level_on(&s, sep);
            assert!(!pieces.is_empty(), "case {case}: {s:?}");
            assert_eq!(pieces.join(&sep_str), s, "case {case}: {s:?} on {sep:?}");
            for p in &pieces {
                assert_eq!(
                    split_top_level_on(p, sep).len(),
                    1,
                    "case {case}: piece {p:?} of {s:?} re-split"
                );
            }
        }
        // The legacy comma entry point is exactly the parametric form.
        assert_eq!(split_top_level(&s), split_top_level_on(&s, ','));
    });
}
