//! Golden equivalence of the simulation fast path (PR 2): the flat-buffer
//! `FlatTrace`, the memoized `TraceCache`/`Replay`, and the campaign
//! `TracePool` must produce **bit-identical** `SimOutcome`s to the seed
//! heap-based `TraceStream` — across all four policy kinds, all fault
//! models, and all three laws (including LogNormal).

use ckptwin::campaign::TracePool;
use ckptwin::config::{FaultModel, PredictorSpec, Scenario};
use ckptwin::model::optimal;
use ckptwin::sim::distribution::Law;
use ckptwin::sim::engine::{simulate, simulate_from, simulate_q, SimOutcome};
use ckptwin::sim::trace::{
    EventSource, FlatTrace, TraceArena, TraceCache, TraceStream,
};
use ckptwin::strategy::{Policy, PolicyKind};

const LAWS: [Law; 3] = [
    Law::Exponential,
    Law::Weibull { shape: 0.7 },
    Law::LogNormal { sigma: 1.2 },
];

const KINDS: [PolicyKind; 4] = [
    PolicyKind::IgnorePredictions,
    PolicyKind::Instant,
    PolicyKind::NoCkpt,
    PolicyKind::WithCkpt,
];

fn fault_models() -> [FaultModel; 3] {
    let n = 1u64 << 16;
    [
        FaultModel::PlatformRenewal,
        FaultModel::PerProcessor { n },
        FaultModel::PerProcessorStationary { n },
    ]
}

/// A scaled-down paper scenario (predictor B: both false predictions and
/// unpredicted faults are present in the trace).
fn scenario(model: FaultModel, law: Law) -> Scenario {
    let mut sc = Scenario::paper(
        1 << 16,
        1.0,
        PredictorSpec::paper_b(900.0),
        law,
        law,
    );
    sc.fault_model = model;
    sc.job_size *= 0.05;
    sc
}

fn policy(sc: &Scenario, kind: PolicyKind) -> Policy {
    let tp = optimal::tp_extr(sc).max(sc.platform.cp * 1.1);
    let tr = optimal::rfo_period(&sc.platform)
        .min(sc.job_size * 0.5)
        .max(1.2 * sc.platform.c);
    Policy { kind, tr, tp }
}

/// All outcomes must be equal in every field, bit for bit (`SimOutcome`
/// derives `PartialEq`; f64 equality is exact and no field is NaN).
fn assert_identical(tag: &str, reference: &SimOutcome, got: &SimOutcome) {
    assert_eq!(reference, got, "{tag}: fast path diverged from reference");
}

#[test]
fn fast_paths_bit_identical_to_reference_stream() {
    for model in fault_models() {
        for law in LAWS {
            let sc = scenario(model, law);
            for kind in KINDS {
                let pol = policy(&sc, kind);
                for seed in [1u64, 9] {
                    let tag = format!("{model:?}/{}/{kind:?}/seed{seed}", law.label());
                    // Reference: the seed heap-based stream.
                    let reference = simulate_from(
                        &sc,
                        &pol,
                        1.0,
                        seed,
                        TraceStream::new(&sc, seed),
                    );
                    // Fast path 1: the flat stream (what `simulate` uses).
                    assert_identical(&tag, &reference, &simulate(&sc, &pol, seed));
                    // Fast path 2: memoized replay, twice (generation pass
                    // and pure-replay pass must agree).
                    let mut cache = TraceCache::new(&sc, seed);
                    let first = simulate_from(&sc, &pol, 1.0, seed, cache.replay());
                    let second = simulate_from(&sc, &pol, 1.0, seed, cache.replay());
                    assert_identical(&tag, &reference, &first);
                    assert_identical(&tag, &reference, &second);
                    // Reference-backed cache (the bench baseline) too.
                    let mut rc = TraceCache::reference(&sc, seed);
                    assert_identical(
                        &tag,
                        &reference,
                        &simulate_from(&sc, &pol, 1.0, seed, rc.replay()),
                    );
                    // Fast path 3: arena-recycled flat stream.
                    let mut arena = TraceArena::new();
                    let mut stream = arena.stream(&sc, seed);
                    let out = simulate_from(&sc, &pol, 1.0, seed, &mut stream);
                    arena.recycle(stream);
                    assert_identical(&tag, &reference, &out);
                }
            }
        }
    }
}

#[test]
fn trace_pool_replays_are_bit_identical_across_policies() {
    let sc = scenario(FaultModel::PerProcessor { n: 1 << 16 }, Law::Weibull { shape: 0.7 });
    let mut pool = TracePool::new();
    for seed in [2u64, 5] {
        for kind in KINDS {
            let pol = policy(&sc, kind);
            let reference =
                simulate_from(&sc, &pol, 1.0, seed, TraceStream::new(&sc, seed));
            let pooled = simulate_from(
                &sc,
                &pol,
                1.0,
                seed,
                pool.replay(0xce11, &sc, seed),
            );
            assert_identical(&format!("pool/{kind:?}/seed{seed}"), &reference, &pooled);
        }
    }
    // 2 seeds × 4 policies: one generation per seed, the rest replays.
    assert_eq!(pool.misses(), 2);
    assert_eq!(pool.hits(), 6);
}

#[test]
fn randomized_trust_uses_identical_coin_flips() {
    // q < 1 exercises the dedicated rng_q stream; it must be independent
    // of which trace implementation feeds the engine.
    let sc = scenario(FaultModel::PlatformRenewal, Law::Exponential);
    let pol = policy(&sc, PolicyKind::Instant);
    for seed in [3u64, 7] {
        let reference = simulate_from(&sc, &pol, 0.5, seed, TraceStream::new(&sc, seed));
        let fast = simulate_q(&sc, &pol, 0.5, seed);
        assert_identical(&format!("q0.5/seed{seed}"), &reference, &fast);
    }
}

#[test]
fn flat_stream_event_sequence_matches_heap_sequence() {
    for model in fault_models() {
        for law in LAWS {
            let sc = scenario(model, law);
            let mut heap = TraceStream::new(&sc, 17);
            let mut flat = FlatTrace::new(&sc, 17);
            for k in 0..1200 {
                assert_eq!(
                    heap.next_event(),
                    flat.next_event(),
                    "{model:?}/{} event {k}",
                    law.label()
                );
            }
        }
    }
}
