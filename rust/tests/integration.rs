//! Cross-module integration tests: analytic model vs discrete-event
//! simulation, harness plumbing, strategies at paper scale.

use ckptwin::config::{FaultModel, Platform, PredictorSpec, Scenario};
use ckptwin::harness::{evaluate_heuristics, run_instances};
use ckptwin::model::optimal;
use ckptwin::model::waste::{self, GridStrategy};
use ckptwin::sim::distribution::Law;
use ckptwin::strategy::{registry, Policy, PolicyKind};

fn paper_scenario(procs: u64, window: f64, law: Law) -> Scenario {
    Scenario::paper(procs, 1.0, PredictorSpec::paper_a(window), law, law)
}

/// The central validity claim of §4.2: for Exponential failures the
/// analytic waste tracks the simulated waste closely (the model is exact up
/// to the one-event-per-interval hypothesis).
#[test]
fn analytic_matches_simulation_exponential() {
    let sc = paper_scenario(1 << 16, 600.0, Law::Exponential);
    for (kind, gs) in [
        (PolicyKind::IgnorePredictions, GridStrategy::Q0),
        (PolicyKind::Instant, GridStrategy::Instant),
        (PolicyKind::NoCkpt, GridStrategy::NoCkpt),
        (PolicyKind::WithCkpt, GridStrategy::WithCkpt),
    ] {
        let tr = match kind {
            PolicyKind::IgnorePredictions => optimal::rfo_period(&sc.platform),
            PolicyKind::Instant => optimal::tr_extr_instant(&sc),
            _ => optimal::tr_extr_window(&sc),
        };
        let tp = optimal::tp_extr(&sc).max(sc.platform.cp * 1.1);
        let pol = Policy { kind, tr, tp };
        let (waste_sim, _) = run_instances(&sc, &pol, 40);
        let predicted = waste::waste_clipped(&sc, gs, tr);
        let diff = (waste_sim.mean() - predicted).abs();
        assert!(
            diff < 0.02,
            "{kind:?}: sim {} vs analytic {predicted}",
            waste_sim.mean()
        );
    }
}

/// Prediction-aware heuristics beat prediction-ignoring ones for a good
/// predictor and short window (Table 4's leftmost column).
#[test]
fn prediction_aware_wins_short_window() {
    let sc = paper_scenario(1 << 16, 300.0, Law::Weibull { shape: 0.7 });
    let res = evaluate_heuristics(&sc, 30, 0);
    let get = |n: &str| res.iter().find(|r| r.name == n).unwrap().makespan;
    let daly = get("Daly");
    for aware in ["Instant", "NoCkptI", "WithCkptI"] {
        let gain = 1.0 - get(aware) / daly;
        assert!(
            gain > 0.08,
            "{aware} gain vs Daly only {:.1}% (paper: ~18%)",
            gain * 100.0
        );
    }
}

/// The paper's Table-4 column shape at 2^19 procs, I=300: gains vs Daly of
/// roughly 45% for prediction-aware and ~18% for RFO (Weibull 0.7).
#[test]
fn table4_gain_ordering_large_platform() {
    let sc = paper_scenario(1 << 19, 300.0, Law::Weibull { shape: 0.7 });
    let res = evaluate_heuristics(&sc, 30, 0);
    let get = |n: &str| res.iter().find(|r| r.name == n).unwrap().makespan;
    let daly = get("Daly");
    let rfo_gain = 1.0 - get("RFO") / daly;
    let aware_gain = 1.0 - get("NoCkptI") / daly;
    assert!(rfo_gain > 0.02, "RFO gain {rfo_gain}");
    assert!(
        aware_gain > rfo_gain,
        "NoCkptI ({aware_gain}) must beat RFO ({rfo_gain})"
    );
}

/// §4.2: "when the prediction window I is shorter than C_p there is no
/// difference between NoCkptI and WithCkptI" (T_P clamps to one period).
#[test]
fn nockpt_equals_withckpt_for_tiny_window() {
    let mut sc = paper_scenario(1 << 17, 300.0, Law::Exponential);
    sc.platform.cp = 1200.0; // I < C_p
    let tr = optimal::tr_extr_window(&sc);
    let tp = optimal::tp_extr(&sc).max(sc.platform.cp * 1.1);
    let (w_no, _) = run_instances(
        &sc,
        &Policy { kind: PolicyKind::NoCkpt, tr, tp },
        30,
    );
    let (w_with, _) = run_instances(
        &sc,
        &Policy { kind: PolicyKind::WithCkpt, tr, tp },
        30,
    );
    // The in-window proactive period exceeds the window: WithCkpt does one
    // slightly-longer cycle; wastes must be near-identical.
    assert!(
        (w_no.mean() - w_with.mean()).abs() < 0.02,
        "NoCkpt {} vs WithCkpt {}",
        w_no.mean(),
        w_with.mean()
    );
}

/// §4.2: WithCkptI becomes the heuristic of choice for large windows with
/// cheap proactive checkpoints.
#[test]
fn withckpt_wins_large_window_cheap_cp() {
    let sc = Scenario::paper(
        1 << 17,
        0.1, // C_p = 0.1 C
        PredictorSpec::paper_a(3000.0),
        Law::Exponential,
        Law::Exponential,
    );
    let res = evaluate_heuristics(&sc, 40, 0);
    let get = |n: &str| res.iter().find(|r| r.name == n).unwrap().waste;
    assert!(
        get("WithCkptI") < get("NoCkptI"),
        "WithCkptI {} vs NoCkptI {}",
        get("WithCkptI"),
        get("NoCkptI")
    );
    assert!(get("WithCkptI") < get("Instant") + 1e-9);
}

/// Daly is measurably off-optimal under Weibull(0.5) while the
/// prediction-aware heuristics stay close to their BestPeriod twins (§4.2,
/// "prediction-aware heuristics are very close to BestPeriod").
#[test]
fn bestperiod_gap_daly_vs_aware_weibull() {
    let sc = paper_scenario(1 << 18, 600.0, Law::Weibull { shape: 0.5 });
    let res = evaluate_heuristics(&sc, 30, 10);
    let get = |n: &str| res.iter().find(|r| r.name == n).unwrap().waste;
    let daly_gap = get("Daly") - get("BestPeriod-NoPred");
    let aware_gap = get("NoCkptI") - get("BestPeriod-NoCkptI");
    assert!(
        daly_gap > aware_gap - 0.01,
        "daly gap {daly_gap} vs aware gap {aware_gap}"
    );
    assert!(aware_gap < 0.06, "aware gap too large: {aware_gap}");
}

/// Waste grows with the platform size (figures 2–13 x-axis trend).
#[test]
fn waste_increases_with_platform_size() {
    let mut prev = 0.0;
    for procs in [1u64 << 16, 1 << 17, 1 << 18, 1 << 19] {
        let sc = paper_scenario(procs, 600.0, Law::Exponential);
        let pol = registry::get("RFO").unwrap().policy(&sc);
        let (w, _) = run_instances(&sc, &pol, 20);
        assert!(
            w.mean() > prev,
            "waste not increasing at N=2^{}",
            procs.trailing_zeros()
        );
        prev = w.mean();
    }
}

/// Degenerate platform params must not panic or hang the engine.
#[test]
fn extreme_parameters_are_safe() {
    let sc = Scenario {
        platform: Platform { mu: 2000.0, c: 600.0, cp: 1200.0, d: 60.0, r: 600.0 },
        predictor: PredictorSpec::paper(0.7, 0.4, 3000.0),
        fault_law: Law::Weibull { shape: 0.5 },
        false_pred_law: Law::Uniform,
        fault_model: FaultModel::PlatformRenewal,
        job_size: 200_000.0,
    };
    for strat in registry::paper_set() {
        let pol = strat.policy(&sc);
        let out = ckptwin::simulate(&sc, &pol, 3);
        assert!(out.makespan.is_finite());
        assert!(out.waste() < 1.0);
    }
}

/// The TOML config front-end drives the same pipeline.
#[test]
fn config_file_to_simulation() {
    let text = r#"
[platform]
procs = 131072
cp = 60.0
[predictor]
recall = 0.85
precision = 0.82
window = 900
[laws]
fault = "exponential"
"#;
    let sc = ckptwin::config::scenario_from_str(text).unwrap();
    let res = evaluate_heuristics(&sc, 10, 0);
    assert_eq!(res.len(), 5);
    assert!(res.iter().all(|r| r.waste > 0.0 && r.waste < 1.0));
}
