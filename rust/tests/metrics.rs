//! Telemetry-layer gates (PR 6).
//!
//! 1. **Recorder transparency** — attaching an [`EventCounters`] recorder
//!    must not perturb a simulation: the recorded outcome equals the
//!    plain one bit for bit, across policy kinds, laws and fault models
//!    (the recorder contract `tests/fast_path.rs`'s goldens rely on).
//! 2. **Waste-accounting audit** — the counter-derived time decomposition
//!    tiles the makespan exactly and reconciles with
//!    `SimOutcome::waste()`, for every `registry::all_defaults()`
//!    strategy under the default predictor.
//! 3. **Timeline cross-check** — the counters' time decomposition equals
//!    the span-level `Timeline::totals_split()` figures.
//! 4. **Golden artifact** — a `METRICS.json`-shaped document (schema
//!    `ckptwin-metrics/1`) round-trips through the JSON parser with the
//!    required headline fields intact.

use ckptwin::config::{FaultModel, PredictorSpec, Scenario};
use ckptwin::model::optimal;
use ckptwin::obs::{report, EventCounters, Hist, MetricsRegistry};
use ckptwin::sim::distribution::Law;
use ckptwin::sim::engine::{simulate_q, simulate_recorded, simulate_traced};
use ckptwin::sim::trace::FlatTrace;
use ckptwin::strategy::{registry, Policy, PolicyKind};

/// Scaled-down paper scenario (predictor B: the trace carries both false
/// predictions and unpredicted faults — every recorder hook fires).
fn scenario(model: FaultModel, law: Law) -> Scenario {
    let mut sc = Scenario::paper(1 << 16, 1.0, PredictorSpec::paper_b(900.0), law, law);
    sc.fault_model = model;
    sc.job_size *= 0.05;
    sc
}

fn policy(sc: &Scenario, kind: PolicyKind) -> Policy {
    let tp = optimal::tp_extr(sc).max(sc.platform.cp * 1.1);
    let tr = optimal::rfo_period(&sc.platform)
        .min(sc.job_size * 0.5)
        .max(1.2 * sc.platform.c);
    Policy { kind, tr, tp }
}

#[test]
fn recorder_is_a_pure_observer_bit_identical_outcomes() {
    let models = [
        FaultModel::PlatformRenewal,
        FaultModel::PerProcessor { n: 1 << 16 },
        FaultModel::PerProcessorStationary { n: 1 << 16 },
    ];
    let laws = [
        Law::Exponential,
        Law::Weibull { shape: 0.7 },
        Law::LogNormal { sigma: 1.2 },
    ];
    let kinds = [
        PolicyKind::IgnorePredictions,
        PolicyKind::Instant,
        PolicyKind::NoCkpt,
        PolicyKind::WithCkpt,
    ];
    for model in models {
        for law in laws {
            let sc = scenario(model, law);
            for kind in kinds {
                let pol = policy(&sc, kind);
                for seed in [1u64, 9] {
                    let tag = format!("{model:?}/{}/{kind:?}/seed{seed}", law.label());
                    let plain = simulate_q(&sc, &pol, 1.0, seed);
                    let mut c = EventCounters::default();
                    let recorded = simulate_recorded(
                        &sc,
                        &pol,
                        1.0,
                        seed,
                        FlatTrace::new(&sc, seed),
                        &mut c,
                    );
                    assert_eq!(plain, recorded, "{tag}: recorder perturbed the simulation");
                    c.audit(&recorded)
                        .unwrap_or_else(|e| panic!("{tag}: audit: {e}"));
                    assert!(c.n_faults > 0, "{tag}: trace had no faults");
                }
            }
        }
    }
}

#[test]
fn recorder_is_transparent_over_sharded_wheel_traces() {
    // The scale-out paths — the timer-wheel generator behind every
    // per-processor `FlatTrace` and the sharded merged source behind
    // shards ≠ 1 campaign cells — honor the same recorder contract as the
    // reference heap path.
    use ckptwin::sim::engine::simulate_from;
    for model in [
        FaultModel::PerProcessor { n: 1 << 16 },
        FaultModel::PerProcessorStationary { n: 1 << 16 },
    ] {
        let sc = scenario(model, Law::Weibull { shape: 0.7 });
        for kind in [PolicyKind::NoCkpt, PolicyKind::WithCkpt] {
            let pol = policy(&sc, kind);
            for seed in [3u64, 12] {
                for shards in [2u32, 4] {
                    let tag = format!("{model:?}/{kind:?}/seed{seed}/shards{shards}");
                    let plain = simulate_from(
                        &sc,
                        &pol,
                        1.0,
                        seed,
                        FlatTrace::sharded(&sc, seed, shards),
                    );
                    let mut c = EventCounters::default();
                    let recorded = simulate_recorded(
                        &sc,
                        &pol,
                        1.0,
                        seed,
                        FlatTrace::sharded(&sc, seed, shards),
                        &mut c,
                    );
                    assert_eq!(plain, recorded, "{tag}: recorder perturbed the run");
                    c.audit(&recorded)
                        .unwrap_or_else(|e| panic!("{tag}: audit: {e}"));
                    assert!(c.n_faults > 0, "{tag}: trace had no faults");
                }
            }
        }
    }
}

#[test]
fn audit_identity_holds_for_every_registered_strategy() {
    // The census the issue demands: every `all_defaults()` strategy —
    // BestPeriod twins included (their policy instantiation searches) —
    // under the default predictor, three seeds each.
    let mut sc = Scenario::paper(
        1 << 16,
        1.0,
        PredictorSpec::paper_a(600.0),
        Law::Exponential,
        Law::Exponential,
    );
    sc.job_size *= 0.02; // keeps the BestPeriod searches cheap
    for strat in registry::all_defaults() {
        let pol = strat.policy(&sc);
        for seed in [0u64, 4, 11] {
            let mut c = EventCounters::default();
            let out = simulate_recorded(&sc, &pol, 1.0, seed, FlatTrace::new(&sc, seed), &mut c);
            c.audit(&out)
                .unwrap_or_else(|e| panic!("{strat}/seed{seed}: audit: {e}"));
            // The audited tiling is exactly the waste identity.
            let waste_from_counters =
                (out.makespan - (c.time_work - c.time_reexec)) / out.makespan;
            assert!(
                (waste_from_counters - out.waste()).abs() <= 1e-6 * out.makespan.max(1.0),
                "{strat}/seed{seed}: counter waste {waste_from_counters} vs \
                 outcome {}",
                out.waste()
            );
        }
    }
}

#[test]
fn counters_match_timeline_span_totals() {
    // Two independent observers of the same engine run — the per-event
    // recorder and the span-level timeline — must tell the same story.
    let sc = scenario(FaultModel::PlatformRenewal, Law::Weibull { shape: 0.7 });
    for kind in [PolicyKind::NoCkpt, PolicyKind::WithCkpt] {
        let pol = policy(&sc, kind);
        for seed in [2u64, 7] {
            let (out, tl) = simulate_traced(&sc, &pol, seed);
            let mut c = EventCounters::default();
            let recorded = simulate_recorded(
                &sc,
                &pol,
                1.0,
                seed,
                FlatTrace::new(&sc, seed),
                &mut c,
            );
            assert_eq!(out, recorded);
            let [work, ckpt_reg, ckpt_pro, down, idle] = tl.totals_split();
            let tol = 1e-6 * out.makespan.max(1.0);
            for (name, a, b) in [
                ("work", c.time_work, work),
                ("ckpt_reg", c.time_ckpt_reg, ckpt_reg),
                ("ckpt_pro", c.time_ckpt_pro, ckpt_pro),
                ("down", c.time_down, down),
                ("idle", c.time_idle, idle),
            ] {
                assert!(
                    (a - b).abs() <= tol,
                    "{kind:?}/seed{seed}: {name}: counters {a} vs timeline {b}"
                );
            }
        }
    }
}

#[test]
fn golden_metrics_document_roundtrips_with_headline_fields() {
    use ckptwin::jsonio::{self, Value};
    use std::collections::BTreeMap;

    // Assemble the same shape `ckptwin metrics` emits, from fixed inputs.
    let mut reg = MetricsRegistry::new();
    reg.add("campaign.cells", 8);
    reg.add("campaign.sim_events", 4096);
    reg.set_gauge("campaign.cells_per_sec", 125.0);
    reg.set_gauge("campaign.events_per_sec", 64000.0);
    reg.set_gauge("campaign.pool_hit_rate", 0.75);
    reg.observe("audit.faults_per_sim", 17);
    let mut decisions = Hist::default();
    for v in [800u64, 1200, 1500, 90_000] {
        decisions.record(v);
    }
    let section = |pairs: Vec<(&str, Value)>| {
        let map: BTreeMap<String, Value> =
            pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
        Value::Obj(map)
    };
    let doc = report::metrics_json(
        &reg,
        &[
            (
                "campaign",
                section(vec![
                    ("cells_per_sec", Value::Num(125.0)),
                    ("events_per_sec", Value::Num(64000.0)),
                    ("pool", section(vec![("hit_rate", Value::Num(0.75))])),
                ]),
            ),
            ("audit", section(vec![("sims", Value::Num(32.0)), ("violations", Value::Num(0.0))])),
            ("coordinator", section(vec![("decision_ns", report::hist_json(&decisions))])),
        ],
    );

    // Write + parse back: the golden round-trip.
    let name = format!("ckptwin-metrics-golden-{}.json", std::process::id());
    let path = std::env::temp_dir().join(name);
    let n = report::write_json(&path, &doc).unwrap();
    assert!(n > 0);
    let text = std::fs::read_to_string(&path).unwrap();
    let back = jsonio::parse(&text).expect("valid JSON");
    let _ = std::fs::remove_file(&path);

    assert_eq!(back.get("schema").and_then(Value::as_str), Some(report::SCHEMA));
    let campaign = back.get("campaign").expect("campaign section");
    assert_eq!(campaign.get("cells_per_sec").and_then(Value::as_f64), Some(125.0));
    assert_eq!(campaign.get("events_per_sec").and_then(Value::as_f64), Some(64000.0));
    let pool = campaign.get("pool").expect("pool section");
    assert_eq!(pool.get("hit_rate").and_then(Value::as_f64), Some(0.75));
    let audit = back.get("audit").expect("audit section");
    assert_eq!(audit.get("violations").and_then(Value::as_usize), Some(0));
    let coord = back.get("coordinator").expect("coordinator section");
    let hist = coord.get("decision_ns").expect("decision histogram");
    assert_eq!(hist.get("count").and_then(Value::as_usize), Some(4));
    assert_eq!(hist.get("max").and_then(Value::as_usize), Some(90_000));
    // The registry carries the merged shard counters too.
    let counters = back.get("registry").and_then(|r| r.get("counters")).expect("counters");
    assert_eq!(counters.get("campaign.sim_events").and_then(Value::as_usize), Some(4096));
}
