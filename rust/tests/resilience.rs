//! Resilience integration tests: the only place fail points are armed
//! end-to-end (library unit tests stick to the pure APIs).
//!
//! Arming is process-global and integration tests share one process, so
//! every test here serializes on [`SERIAL`] — without it, one test's plan
//! would fire inside another's workload.

use std::path::PathBuf;
use std::sync::Mutex;

use ckptwin::campaign::scheduler;
use ckptwin::campaign::store::{CellRecord, Store};
use ckptwin::config::{FaultModel, Platform, PredictorSpec, Scenario};
use ckptwin::coordinator::workload::SyntheticWorkload;
use ckptwin::coordinator::{self, CoordinatorConfig, SelfCkptOptions};
use ckptwin::resilience::chaos::{self, ChaosOptions};
use ckptwin::resilience::failpoint::{self, Plan, Site};
use ckptwin::resilience::retry::{self, Backoff};
use ckptwin::resilience::snapshot::SnapshotStore;
use ckptwin::sim::distribution::Law;
use ckptwin::strategy::{Policy, PolicyKind};

static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    // A panicking test (some tests *expect* panics inside workers) must
    // not wedge the rest of the suite behind a poisoned lock.
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp_file(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "ckptwin-resilience-{tag}-{}.jsonl",
        std::process::id()
    ))
}

fn rec(i: u64) -> CellRecord {
    CellRecord {
        hash: 0x1000 + i,
        key: format!("cell-{i}"),
        instances: 50,
        waste_mean: 0.25 + i as f64 * 0.01,
        waste_var: 0.002,
        waste_ci95: 0.01,
        waste_min: 0.1,
        waste_max: 0.5,
        makespan_mean: 9000.0 + i as f64,
        tr: 1000.0,
    }
}

/// A crash that tears the JSONL tail mid-record loses exactly the torn
/// line; reopening repairs the tail, keeps every durable record, and the
/// repair is idempotent.
#[test]
fn torn_tail_crash_resume_loses_no_durable_record() {
    let _g = lock();
    let path = tmp_file("torn");
    let _ = std::fs::remove_file(&path);
    let mut store = Store::create(&path).unwrap();
    for i in 0..5 {
        store.append(&rec(i)).unwrap();
    }
    {
        let _arm = failpoint::arm(Plan::parse("jsonl.tail:nth=1,mode=torn").unwrap());
        let err = store.append(&rec(5)).unwrap_err();
        assert!(err.to_string().contains("injected"), "{err:#}");
        assert_eq!(failpoint::fired(Site::JsonlTail), 1);
    }
    drop(store);

    // The resume: the torn tail is detected, truncated away, and the
    // record that was mid-write is simply absent (never acknowledged).
    let mut store = Store::open(&path).unwrap();
    assert_eq!(store.skipped_lines, 1, "torn tail not detected");
    assert_eq!(store.len(), 5);
    store.append(&rec(5)).unwrap();
    drop(store);

    // Idempotence: the repaired fragment persists as one inert skipped
    // line; further reopens converge (same skips, all six records).
    for _ in 0..2 {
        let store = Store::open(&path).unwrap();
        assert_eq!(store.skipped_lines, 1);
        assert_eq!(store.len(), 6);
        assert_eq!(store.get(rec(5).hash), Some(&rec(5)));
    }
    let _ = std::fs::remove_file(&path);
}

/// Transient IO faults at `store.append` are absorbed by the bounded
/// backoff retry — the caller never sees them, the record lands.
#[test]
fn transient_io_faults_are_absorbed_by_bounded_retry() {
    let _g = lock();
    let path = tmp_file("transient");
    let _ = std::fs::remove_file(&path);
    let before = retry::total_retries();
    let mut store = Store::create(&path).unwrap();
    {
        let _arm =
            failpoint::arm(Plan::parse("store.append:nth=1,mode=transient").unwrap());
        store.append(&rec(0)).unwrap();
        assert_eq!(failpoint::fired(Site::StoreAppend), 1);
    }
    assert!(retry::total_retries() > before, "retry counter did not move");
    drop(store);
    let store = Store::open(&path).unwrap();
    assert_eq!(store.len(), 1);
    assert_eq!(store.skipped_lines, 0);
    let _ = std::fs::remove_file(&path);
}

/// A worker panic is contained: the unit is requeued and succeeds on the
/// retry; with retries exhausted, the failure manifest names each unit.
#[test]
fn contained_scheduler_requeues_and_reports_failures() {
    let _g = lock();
    {
        let _arm = failpoint::arm(Plan::parse("sched.worker:nth=2,mode=panic").unwrap());
        let run = scheduler::run_units_contained(4, 1, 2, || (), |_, i| i * 10);
        assert!(run.failures.is_empty(), "{:?}", run.failures);
        assert_eq!(run.results, vec![Some(0), Some(10), Some(20), Some(30)]);
        assert_eq!(failpoint::fired(Site::SchedWorker), 1);
    }
    {
        let _arm = failpoint::arm(Plan::parse("sched.worker:p=1.0,mode=panic").unwrap());
        let run = scheduler::run_units_contained(3, 1, 1, || (), |_, i| i);
        assert_eq!(run.results, vec![None, None, None]);
        assert_eq!(run.failures.len(), 3);
        for (k, f) in run.failures.iter().enumerate() {
            assert_eq!(f.unit, k);
            assert_eq!(f.attempts, 2, "1 try + 1 retry");
            assert!(f.message.contains("sched.worker"), "{}", f.message);
        }
    }
}

/// The stateful scheduler (no containment budget) panics with a message
/// that names the unit index — the satellite's debuggability contract.
#[test]
fn stateful_scheduler_panic_names_the_unit() {
    let _g = lock();
    let _arm = failpoint::arm(Plan::parse("sched.worker:p=1.0,mode=panic").unwrap());
    let caught = std::panic::catch_unwind(|| {
        scheduler::run_units_stateful(2, 1, || (), |_: &mut (), i| i)
    });
    let payload = caught.expect_err("expected the run to panic");
    let msg = payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(
        msg.contains("unit 0 panicked after 1 attempt(s)"),
        "unhelpful panic message: {msg}"
    );
}

fn coord_config(tag: &str) -> CoordinatorConfig {
    let scenario = Scenario {
        platform: Platform { mu: 3000.0, c: 120.0, cp: 60.0, d: 30.0, r: 60.0 },
        predictor: PredictorSpec::paper(0.85, 0.82, 240.0),
        fault_law: Law::Exponential,
        false_pred_law: Law::Exponential,
        fault_model: FaultModel::PlatformRenewal,
        job_size: 0.0,
    };
    let dir = std::env::temp_dir().join(format!(
        "ckptwin-resilience-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    CoordinatorConfig {
        scenario,
        policy: Policy { kind: PolicyKind::WithCkpt, tr: 1000.0, tp: 200.0 },
        seconds_per_step: 30.0,
        total_steps: 200,
        ckpt_dir: dir,
        seed: 11,
        log_every: 10,
        selfckpt: Some(SelfCkptOptions { crash_mtbf_passes: 60.0, replan_every: 1 }),
    }
}

/// The crash–resume equivalence contract, end to end: a coordinator killed
/// mid-run (injected `coord.pass` fault) and resumed from its own snapshot
/// produces the identical Report fingerprint to an uninterrupted run.
#[test]
fn coordinator_killed_mid_run_resumes_to_the_golden_report() {
    let _g = lock();
    let golden_cfg = coord_config("golden");
    let golden = coordinator::run(&golden_cfg, &mut SyntheticWorkload::new(32)).unwrap();
    // Crash past the bootstrap snapshot (pass 16) so a resume point exists.
    assert!(golden.passes > 40, "run too short to crash mid-way");

    let cfg = coord_config("crash");
    let snaps = SnapshotStore::new(&cfg.ckpt_dir).unwrap();
    let nth = 1 + golden.passes / 2;
    let mut resume = None;
    let mut crashes = 0u64;
    let rep = loop {
        let attempt = {
            let _arm = if crashes == 0 {
                // First attempt: killed mid-run at pass `nth`.
                Some(failpoint::arm(
                    Plan::parse(&format!("coord.pass:nth={nth},mode=transient")).unwrap(),
                ))
            } else {
                None // the restarted process runs clean to completion
            };
            coordinator::run_from(&cfg, &mut SyntheticWorkload::new(32), resume.as_ref())
        };
        match attempt {
            Ok(rep) => break rep,
            Err(e) => {
                assert!(e.to_string().contains("injected"), "{e:#}");
                crashes += 1;
                resume = snaps.load().unwrap();
                assert!(resume.is_some(), "crashed before the first self-snapshot");
            }
        }
    };
    assert_eq!(crashes, 1, "the injected crash should fire exactly once");
    assert_eq!(rep.fingerprint(), golden.fingerprint());
    assert_eq!(rep.losses, golden.losses);
    assert_eq!(rep.passes, golden.passes);
    assert_eq!(rep.steps_executed, golden.steps_executed);
    let _ = std::fs::remove_dir_all(&golden_cfg.ckpt_dir);
    let _ = std::fs::remove_dir_all(&cfg.ckpt_dir);
}

/// A short chaos gate run comes back clean and its CHAOS.json round-trips.
#[test]
fn chaos_gate_smoke_is_clean() {
    let _g = lock();
    let dir = std::env::temp_dir().join(format!(
        "ckptwin-resilience-chaos-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let rep =
        chaos::run_chaos(&ChaosOptions { cycles: 6, seed: 9, dir: dir.clone() }).unwrap();
    assert!(rep.ok(), "divergences: {:?}", rep.divergences);
    assert_eq!(rep.cycles_run, 6);
    assert_eq!(rep.resumes, rep.crashes_injected);

    let json = dir.join("CHAOS.json");
    let bytes = chaos::write_chaos_json(&json, &rep).unwrap();
    assert!(bytes > 0);
    let doc = ckptwin::jsonio::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
    assert_eq!(doc.get("schema").unwrap().as_str(), Some(chaos::SCHEMA));
    assert_eq!(doc.get("ok"), Some(&ckptwin::jsonio::Value::Bool(true)));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite (f), integration-visible: the backoff schedule is pure in
/// (seed, attempt) and bounded by the cap.
#[test]
fn backoff_schedule_is_a_pure_function_of_seed_and_attempt() {
    let b = Backoff { base_ms: 3, cap_ms: 50, attempts: 6, seed: 0xfeed };
    let one: Vec<u64> = (1..=8).map(|a| b.delay_ms(a)).collect();
    let two: Vec<u64> = (1..=8).map(|a| b.delay_ms(a)).collect();
    assert_eq!(one, two);
    assert!(one.iter().all(|&d| (1..=50).contains(&d)), "{one:?}");
    let other = Backoff { seed: 0xbeef, ..b };
    assert_ne!(one, (1..=8).map(|a| other.delay_ms(a)).collect::<Vec<_>>());
}
