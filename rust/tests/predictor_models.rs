//! Predictor-engine integration tests: the online/offline substream
//! dedupe (feed ≡ trace, bit for bit), the §2.2 before-t = 0
//! announcement-drop convention on both paths, the per-announcement trust
//! weight in the engine, and every registry predictor running end-to-end
//! through trace generation and a campaign grid.

use ckptwin::campaign::{self, CampaignOptions, Grid};
use ckptwin::config::{FaultModel, PredModel, Scenario};
use ckptwin::predictor::{self, registry as predictors};
use ckptwin::sim::distribution::Law;
use ckptwin::sim::engine::simulate_from;
use ckptwin::sim::trace::{Event, EventSource, Prediction, TraceStream};
use ckptwin::strategy::{registry, Policy, PolicyKind};
use ckptwin::{PredictorSpec, StrategyId};

fn scenario(spec: PredictorSpec) -> Scenario {
    let mut sc = Scenario::paper(
        1 << 16,
        1.0,
        spec,
        Law::Exponential,
        Law::Exponential,
    );
    sc.fault_model = FaultModel::PlatformRenewal;
    sc
}

/// Sort key making prediction comparisons order-insensitive on exact
/// notify ties (the trace orders by visible time, the feed by notify).
fn sort_preds(mut v: Vec<Prediction>) -> Vec<Prediction> {
    v.sort_by(|a, b| {
        a.notify_t
            .total_cmp(&b.notify_t)
            .then(a.window_start.total_cmp(&b.window_start))
            .then(a.window_end.total_cmp(&b.window_end))
    });
    v
}

/// Satellite: `predictor::feed` and the trace substream generators are ONE
/// code path — for identical (fault schedule, seed) pairs the online feed
/// and the offline trace emit bit-identical announcement sequences.
#[test]
fn online_feed_matches_trace_substreams_bit_for_bit() {
    for spec in [
        PredictorSpec::paper_b(900.0),
        predictors::PredictorId::parse("mixedwin(i1=300;i2=1200;w=0.5;r=0.7;p=0.4)")
            .unwrap()
            .spec(900.0),
        predictors::PredictorId::parse("classed(p_hi=0.95;p_lo=0.6;frac=0.5;r=0.7)")
            .unwrap()
            .spec(900.0),
    ] {
        let sc = scenario(spec);
        let (cp, mu) = (sc.platform.cp, sc.platform.mu);
        let horizon = 50.0 * mu;
        for seed in [1u64, 8] {
            let evs = TraceStream::new(&sc, seed).take_until(horizon);
            let faults: Vec<f64> = evs
                .iter()
                .filter_map(|e| match e {
                    Event::Fault { t, .. } => Some(*t),
                    _ => None,
                })
                .collect();
            assert!(faults.len() > 20, "need a real schedule");
            let feed = predictor::feed(
                &faults,
                &sc.predictor,
                cp,
                mu,
                sc.false_pred_law,
                horizon,
                seed,
            );
            // Compare away from the horizon edges: a trace prediction with
            // notify below this bound provably comes from a raw arrival
            // below `horizon` (and vice versa), so both sides hold the
            // complete set there.
            let h_cmp = horizon
                - (sc.predictor.max_window()
                    + sc.predictor.placement_slack()
                    + cp);
            let from_trace = sort_preds(
                evs.iter()
                    .filter_map(|e| match e {
                        Event::Prediction(p) if p.notify_t < h_cmp => Some(*p),
                        _ => None,
                    })
                    .collect(),
            );
            let from_feed = sort_preds(
                feed.into_iter().filter(|a| a.notify_t < h_cmp).collect(),
            );
            assert!(!from_trace.is_empty());
            assert_eq!(
                from_trace.len(),
                from_feed.len(),
                "{}/seed{seed}: announcement counts diverge",
                sc.predictor.model
            );
            for (k, (a, b)) in from_trace.iter().zip(&from_feed).enumerate() {
                assert_eq!(
                    a, b,
                    "{}/seed{seed}: announcement {k} diverges",
                    sc.predictor.model
                );
            }
        }
    }
}

/// Satellite: the §2.2 convention — a prediction whose announcement would
/// land before t = 0 is dropped and its fault reclassified as unpredicted —
/// pinned on both the offline trace and the online feed, with the
/// recall-accounting consequence for `predictor::score`.
#[test]
fn pre_t0_announcements_reclassified_on_both_paths() {
    // Offline path: recall 1, precision 1 — every fault would be predicted,
    // so any unpredicted fault in the trace is a t = 0 reclassification.
    let mut spec = PredictorSpec::paper(1.0, 1.0, 2000.0);
    let mut sc = scenario(spec);
    sc.platform.mu = 100.0; // dense faults: some strike before cp = 600
    let evs = TraceStream::new(&sc, 3).take_until(50_000.0);
    let thresh = sc.predictor.window + sc.platform.cp;
    let mut early_unpredicted = 0;
    for e in &evs {
        match e {
            Event::Prediction(p) => {
                assert!(p.notify_t >= 0.0, "announced before t = 0: {p:?}");
            }
            Event::Fault { t, predicted } => {
                if *t >= thresh {
                    // Past I + C_p the announcement always fits: predicted.
                    assert!(*predicted, "late fault at {t} unpredicted");
                } else if !*predicted {
                    early_unpredicted += 1;
                }
            }
        }
    }
    assert!(early_unpredicted > 0, "seed produced no early fault");

    // Online path, deterministic by construction: faults below C_p can
    // never be announced (notify = t − offset − C_p < 0 for any offset),
    // faults beyond I + C_p always can.
    spec = PredictorSpec::paper(1.0, 1.0, 5000.0);
    let cp = 600.0;
    let faults: Vec<f64> =
        vec![100.0, 500.0, 10_000.0, 20_000.0, 30_000.0, 40_000.0, 50_000.0];
    let feed =
        predictor::feed(&faults, &spec, cp, 10_000.0, Law::Exponential, 1e6, 9);
    assert_eq!(feed.len(), 5, "the two pre-C_p faults must be dropped");
    assert!(feed.iter().all(|a| a.notify_t >= 0.0 && a.true_positive));
    // Recall accounting: score charges the dropped announcements against
    // the predictor — measured recall is 5/7, not the nominal 1.0.
    let (recall, precision) = predictor::score(&faults, &feed);
    assert_eq!(precision, 1.0);
    assert!((recall - 5.0 / 7.0).abs() < 1e-12, "recall {recall}");
}

/// The engine's per-announcement trust weight, pinned deterministically:
/// an announcement with weight 0 is never trusted, weight 1 always (at
/// q = 1), and the paper's weight-1 announcements leave the q coin-flip
/// stream untouched (`tests/fast_path.rs` pins the latter globally).
#[test]
fn engine_honours_announcement_trust_weights() {
    struct Scripted(Vec<Event>, usize);
    impl EventSource for Scripted {
        fn next_event(&mut self) -> Event {
            let ev = self.0.get(self.1).copied().unwrap_or(Event::Fault {
                t: f64::INFINITY,
                predicted: false,
            });
            self.1 += 1;
            ev
        }
    }
    let pred = |notify: f64, weight: f64| {
        Event::Prediction(Prediction {
            notify_t: notify,
            window_start: notify + 600.0,
            window_end: notify + 1600.0,
            true_positive: false,
            weight,
        })
    };
    let mut sc = scenario(PredictorSpec::paper(0.5, 0.5, 1000.0));
    sc.platform.mu = 1e9; // fault-free
    sc.job_size = 20_000.0;
    let pol = Policy { kind: PolicyKind::NoCkpt, tr: 3600.0, tp: 1200.0 };
    let stream = Scripted(vec![pred(1000.0, 0.0), pred(8000.0, 1.0)], 0);
    let out = simulate_from(&sc, &pol, 1.0, 0, stream);
    assert_eq!(out.n_preds_seen, 2);
    assert_eq!(
        out.n_preds_trusted, 1,
        "weight 0 must be ignored, weight 1 trusted"
    );
}

/// Acceptance: every registry predictor runs end-to-end — sorted trace
/// generation, simulation, campaign grid cells with distinct store
/// identities and paired fault environments.
#[test]
fn every_registry_predictor_runs_end_to_end() {
    // Trace level: sorted events, well-formed windows, exact lead time.
    for pid in predictors::all_defaults() {
        let sc = scenario(pid.spec(900.0));
        let evs = TraceStream::new(&sc, 2).take_until(60.0 * sc.platform.mu);
        assert!(evs.len() > 50, "{pid}");
        for w in evs.windows(2) {
            assert!(w[0].time() <= w[1].time(), "{pid}: {w:?}");
        }
        for e in &evs {
            if let Event::Prediction(p) = e {
                assert!(p.notify_t >= 0.0, "{pid}");
                assert!(p.window_end > p.window_start, "{pid}");
                // Lead time is exactly C_p for every model (jitter moves
                // the window, not the announcement-to-window gap).
                assert!(
                    (p.window_start - p.notify_t - sc.platform.cp).abs()
                        < 1e-9 * p.window_start.abs().max(1.0),
                    "{pid}: {p:?}"
                );
                assert!(p.weight > 0.0 && p.weight <= 1.0, "{pid}");
            }
        }
    }

    // Campaign level: one grid over five distinct predictor models.
    let grid = Grid {
        procs: vec![1 << 16],
        cp_ratios: vec![1.0],
        fault_laws: vec![Law::Exponential],
        uniform_false_preds: false,
        predictors: vec![
            predictors::get("a").unwrap(),
            predictors::get("biased").unwrap(),
            predictors::get("mixedwin").unwrap(),
            predictors::get("jitter").unwrap(),
            predictors::get("classed").unwrap(),
        ],
        windows: vec![600.0],
        strategies: vec![
            registry::get("NoCkptI").unwrap(),
            StrategyId::parse("qtrust(q=0.5)").unwrap(),
        ],
        scale: 0.02,
        platform_shards: vec![1],
    };
    let cells = grid.expand();
    assert_eq!(cells.len(), 10);
    let opt = CampaignOptions { instances: 3, block: 2, threads: 2 };
    let outcomes = campaign::evaluate_grid(&grid, &opt);
    assert_eq!(outcomes.len(), 10, "no two predictor cells may collide");
    let mut hashes: Vec<u64> = outcomes.iter().map(|o| o.cell.hash).collect();
    hashes.sort_unstable();
    hashes.dedup();
    assert_eq!(hashes.len(), 10);
    for o in &outcomes {
        assert!(
            o.waste.mean() > 0.0 && o.waste.mean() < 1.0,
            "{}: waste {}",
            o.cell.key(),
            o.waste.mean()
        );
        // All predictors at one scenario point share the fault environment
        // (paired comparisons across the predictor axis).
        assert_eq!(o.cell.trace_hash, outcomes[0].cell.trace_hash);
    }
}

/// The jitter model's honesty: lead time stays exact while some faults
/// escape their announced window — recorded as unpredicted faults plus
/// uncovering announcements, which depresses the *measured* recall.
#[test]
fn jitter_reduces_effective_recall() {
    let spec = predictors::PredictorId::parse("jitter(sigma=600;r=1;p=1)")
        .unwrap()
        .spec(600.0);
    assert_eq!(spec.model, PredModel::Jitter { sigma: 600.0 });
    let sc = scenario(spec);
    let evs = TraceStream::new(&sc, 4).take_until(300.0 * sc.platform.mu);
    let (mut faults, mut unpredicted, mut missing_windows) = (0u64, 0u64, 0u64);
    for e in &evs {
        match e {
            Event::Fault { predicted, .. } => {
                faults += 1;
                unpredicted += !*predicted as u64;
            }
            Event::Prediction(p) => {
                missing_windows += !p.true_positive as u64;
            }
        }
    }
    assert!(faults > 100);
    // σ = I: a large share of windows miss (≈ 62% analytically).
    assert!(
        unpredicted as f64 > 0.3 * faults as f64,
        "{unpredicted}/{faults}"
    );
    // Every miss shows up symmetrically as a non-covering announcement
    // (precision 1 ⇒ there is no false-prediction substream, so every
    // non-true-positive announcement is a missed window; the counts can
    // differ only by pre-t = 0 drops — window removed, unpredicted fault
    // kept — and a horizon-edge window or two whose fault lies beyond the
    // materialized events).
    assert!(missing_windows <= unpredicted + 2, "{missing_windows} vs {unpredicted}");
    assert!(missing_windows as f64 > 0.8 * unpredicted as f64);
}

/// The classed model's announcements carry both weights at the Bayes
/// frequencies, and the engine's NoCkpt q = 1 run ignores a fraction of
/// the low-confidence class (the QTrust pairing).
#[test]
fn classed_announcements_carry_confidence_weights() {
    let spec = predictors::get("classed").unwrap().spec(600.0);
    let (p_hi, p_lo) = (0.95, 0.6);
    assert!((spec.precision - (0.5 * p_hi + 0.5 * p_lo)).abs() < 1e-12);
    let sc = scenario(spec);
    let evs = TraceStream::new(&sc, 5).take_until(400.0 * sc.platform.mu);
    let (mut hi, mut lo) = (0u64, 0u64);
    for e in &evs {
        if let Event::Prediction(p) = e {
            if p.weight == 1.0 {
                hi += 1;
            } else {
                assert!((p.weight - p_lo / p_hi).abs() < 1e-12, "{p:?}");
                lo += 1;
            }
        }
    }
    assert!(hi > 50 && lo > 50, "hi {hi} lo {lo}");
    // frac = 0.5: the two classes are roughly balanced overall.
    let frac = hi as f64 / (hi + lo) as f64;
    assert!((frac - 0.5).abs() < 0.1, "{frac}");

    // Engine pairing: with full trust (q = 1) the low class is still only
    // trusted with probability p_lo/p_hi, so some listened-to
    // announcements are ignored — impossible under the paper predictor,
    // whose q = 1 runs only skip announcements that overlap activity.
    let pol = registry::get("NoCkptI").unwrap().policy(&sc);
    let out = ckptwin::simulate(&sc, &pol, 6);
    assert!(
        out.n_preds_trusted + out.n_preds_overlapped < out.n_preds_seen,
        "some low-class announcements must be ignored: {out:?}"
    );
}
