//! Million-processor scale-out gates (PR 8).
//!
//! 1. **Wheel-vs-heap equivalence** — the timer-wheel per-processor
//!    source behind `FlatTrace` must emit the *bit-identical* event
//!    sequence to the heap-backed reference `TraceStream`, across Weibull
//!    shapes, fresh/stationary pools and seeds (the RNG-draw-order
//!    contract of `sim::trace::PerProcCore`).
//! 2. **Sorted, deterministic streams at scale** — plain and sharded
//!    traces at N = 10^5 are nondecreasing in time and reproduce exactly
//!    under a repeated seed.
//! 3. **Stationary rate law** — the measured superposed platform rate of a
//!    stationary pool at N = 10^5 is 1/μ (the statistical mirror of
//!    `stationary_per_proc_rate_is_one_over_mu`).
//! 4. **Sharded campaign equivalence** — a shards = 4 campaign cell at
//!    N = 2^20 aggregates bit-identically whether the scheduler runs one
//!    worker or several (block-ordered Welford merges), and its waste
//!    agrees statistically with the unsharded cell's.

use ckptwin::campaign::{self, CampaignOptions, Cell, Grid};
use ckptwin::config::{FaultModel, PredictorSpec, Scenario};
use ckptwin::sim::distribution::Law;
use ckptwin::sim::trace::{
    measured_fault_rate, Event, EventSource, FlatTrace, TraceStream,
};
use ckptwin::strategy::registry;

/// Scaled-down paper scenario on a per-processor pool (predictor B: the
/// trace carries both false predictions and unpredicted faults).
fn scenario(model: FaultModel, shape: f64) -> Scenario {
    let n = match model {
        FaultModel::PerProcessor { n }
        | FaultModel::PerProcessorStationary { n } => n,
        FaultModel::PlatformRenewal => 1 << 16,
    };
    let law = Law::Weibull { shape };
    let mut sc = Scenario::paper(n, 1.0, PredictorSpec::paper_b(900.0), law, law);
    sc.fault_model = model;
    sc.job_size *= 0.05;
    sc
}

fn collect<S: EventSource>(src: &mut S, horizon: f64, cap: usize) -> Vec<Event> {
    let mut out = Vec::new();
    while out.len() < cap {
        let ev = src.next_event();
        if ev.time() >= horizon {
            break;
        }
        out.push(ev);
    }
    out
}

#[test]
fn wheel_trace_bit_identical_to_heap_trace() {
    // 3 shapes × fresh/stationary × 3 seeds: the full event stream (faults,
    // true windows, false predictions) must match the heap reference bit
    // for bit — f64 equality is exact and the generators emit no NaN.
    let n = 1u64 << 14;
    for shape in [0.5, 0.7, 1.5] {
        for model in [
            FaultModel::PerProcessor { n },
            FaultModel::PerProcessorStationary { n },
        ] {
            let sc = scenario(model, shape);
            let horizon = 12.0 * sc.platform.mu;
            for seed in [1u64, 5, 11] {
                let heap = TraceStream::new(&sc, seed).take_until(horizon);
                let wheel =
                    collect(&mut FlatTrace::new(&sc, seed), horizon, usize::MAX);
                assert!(!heap.is_empty(), "shape {shape}: degenerate horizon");
                assert_eq!(
                    heap, wheel,
                    "shape {shape} {model:?} seed {seed}: wheel diverged from heap"
                );
            }
        }
    }
}

#[test]
fn traces_at_1e5_procs_are_sorted_and_deterministic() {
    let n = 100_000u64;
    for (label, shards) in [("plain", 1u32), ("sharded", 4)] {
        let sc = scenario(FaultModel::PerProcessorStationary { n }, 0.7);
        let horizon = 25.0 * sc.platform.mu;
        let a = collect(&mut FlatTrace::sharded(&sc, 7, shards), horizon, 50_000);
        let b = collect(&mut FlatTrace::sharded(&sc, 7, shards), horizon, 50_000);
        assert!(a.len() > 100, "{label}: only {} events", a.len());
        assert_eq!(a, b, "{label}: trace not reproducible under its seed");
        for w in a.windows(2) {
            assert!(
                w[0].time() <= w[1].time(),
                "{label}: events out of order at t = {}",
                w[1].time()
            );
        }
    }
}

#[test]
fn stationary_rate_at_1e5_procs_is_one_over_mu() {
    // The superposition of N stationary renewal processes has rate
    // N/μ_ind = 1/μ at every t — measured through the full wheel path.
    // 6 seeds × 150 MTBFs ≈ 900 faults: sampling σ ≈ 3.3%, so the 10%
    // tolerance sits at 3σ.
    let sc = scenario(FaultModel::PerProcessorStationary { n: 100_000 }, 0.7);
    let horizon = 150.0 * sc.platform.mu;
    let mut rate = 0.0;
    let seeds = 6u64;
    for seed in 0..seeds {
        rate += measured_fault_rate(&sc, seed, horizon);
    }
    rate /= seeds as f64;
    let expected = 1.0 / sc.platform.mu;
    let rel = (rate / expected - 1.0).abs();
    assert!(rel < 0.10, "measured {rate} vs 1/mu {expected} (rel {rel})");
}

fn scale_grid(shards: u32) -> Grid {
    Grid {
        procs: vec![1 << 20],
        cp_ratios: vec![1.0],
        fault_laws: vec![Law::Weibull { shape: 0.7 }],
        uniform_false_preds: false,
        predictors: vec![ckptwin::predictor::registry::get("a").unwrap()],
        windows: vec![600.0],
        strategies: vec![
            registry::get("RFO").unwrap(),
            registry::get("NoCkptI").unwrap(),
        ],
        scale: 0.05,
        platform_shards: vec![shards],
    }
}

fn outcome_fingerprint(outcomes: &[campaign::CellOutcome]) -> Vec<(u64, u64, u64, u64, usize)> {
    outcomes
        .iter()
        .map(|o| {
            (
                o.cell.hash,
                o.waste.mean().to_bits(),
                o.waste.ci95().to_bits(),
                o.makespan.mean().to_bits(),
                o.waste.len(),
            )
        })
        .collect()
}

#[test]
fn sharded_megaproc_cell_aggregates_identically_across_workers() {
    // The pinned scale-out equivalence: a 2^20-processor cell split into 4
    // shard sub-sources must produce the SAME Welford aggregate whether
    // the campaign runs sequentially or on several stealing workers — the
    // scheduler's block-ordered merge makes parallel execution a pure
    // speedup, shards included.
    let cells: Vec<Cell> = scale_grid(4).expand();
    assert_eq!(cells.len(), 2);
    for c in &cells {
        assert!(c.trace_key().ends_with(";shards=4"), "{}", c.trace_key());
    }
    let opt1 = CampaignOptions { instances: 4, block: 2, threads: 1 };
    let opt3 = CampaignOptions { instances: 4, block: 2, threads: 3 };
    let (seq, _) = campaign::run_cells(&cells, &opt1, None).unwrap();
    let (par, _, m) =
        campaign::run_cells_metered(&cells, &opt3, None, false).unwrap();
    assert_eq!(
        outcome_fingerprint(&seq),
        outcome_fingerprint(&par),
        "parallel sharded aggregate diverged from the sequential run"
    );
    // The metered run surfaces scale-out health: wheel pops on every
    // generated fault, shard merges on every merged event.
    assert!(m.wheel_pops > 0, "no wheel activity recorded");
    assert!(m.shard_merges > 0, "no shard merges recorded");
}

#[test]
fn sharded_and_unsharded_cells_agree_statistically() {
    // Shards ≠ 1 defines a *different* (equally distributed) trace — the
    // pool is partitioned across derived seed streams — so the aggregates
    // agree statistically, not bitwise.
    let opt = CampaignOptions { instances: 8, block: 0, threads: 0 };
    let (one, _) = campaign::run_cells(&scale_grid(1).expand(), &opt, None).unwrap();
    let (four, _) = campaign::run_cells(&scale_grid(4).expand(), &opt, None).unwrap();
    assert_eq!(one.len(), four.len());
    for (a, b) in one.iter().zip(&four) {
        assert_ne!(a.cell.hash, b.cell.hash, "shard axis must separate hashes");
        let d = (a.waste.mean() - b.waste.mean()).abs();
        let tol = 0.03f64.max(5.0 * (a.waste.ci95() + b.waste.ci95()));
        assert!(
            d <= tol,
            "{}: waste {} (S=1) vs {} (S=4), |d| {d} > tol {tol}",
            a.cell.key(),
            a.waste.mean(),
            b.waste.mean()
        );
    }
}
