//! PJRT artifact round-trip tests: the L1 Pallas kernel (via its HLO
//! artifact) must agree with the Rust closed-form model, and the training
//! artifacts must initialize, step and eval coherently.
//!
//! These tests require `make artifacts`; they skip (with a note) otherwise.

use ckptwin::config::{PredictorSpec, Scenario};
use ckptwin::model::waste::{waste_clipped, GridStrategy};
use ckptwin::runtime::train::Trainer;
use ckptwin::runtime::Runtime;
use ckptwin::sim::distribution::Law;

fn runtime_or_skip() -> Option<Runtime> {
    if !Runtime::artifacts_present() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::discover().expect("runtime"))
}

fn scenarios() -> Vec<Scenario> {
    let mut out = Vec::new();
    for procs in [1u64 << 16, 1 << 18, 1 << 19] {
        for cp_ratio in [1.0, 0.1, 2.0] {
            for window in [300.0, 1200.0, 3000.0] {
                for pred in [
                    PredictorSpec::paper_a(window),
                    PredictorSpec::paper_b(window),
                ] {
                    out.push(Scenario::paper(
                        procs,
                        cp_ratio,
                        pred,
                        Law::Exponential,
                        Law::Exponential,
                    ));
                }
            }
        }
    }
    out
}

/// The kernel (through jax lowering, HLO text, PJRT compilation, f32) and
/// the Rust f64 closed form agree on the full scenario battery.
#[test]
fn waste_grid_artifact_matches_rust_model() {
    let Some(rt) = runtime_or_skip() else { return };
    let scs = scenarios();
    let grid: Vec<f64> = (0..64).map(|k| 650.0 + 900.0 * k as f64).collect();
    let surfaces = rt.waste_surfaces(&scs, &grid).expect("waste_surfaces");
    assert_eq!(surfaces.len(), scs.len());
    let strategies = [
        GridStrategy::Q0,
        GridStrategy::Instant,
        GridStrategy::NoCkpt,
        GridStrategy::WithCkpt,
    ];
    let mut checked = 0usize;
    for (sc, surface) in scs.iter().zip(&surfaces) {
        for (si, gs) in strategies.iter().enumerate() {
            for (gi, &tr) in grid.iter().enumerate() {
                let got = surface[si][gi] as f64;
                let want = waste_clipped(sc, *gs, tr);
                assert!(
                    (got - want).abs() < 2e-4,
                    "strategy {si} tr {tr}: artifact {got} vs rust {want}\n{sc:?}"
                );
                checked += 1;
            }
        }
    }
    assert_eq!(checked, scs.len() * 4 * 64);
}

/// Argmin over the artifact grid lands near the closed-form optimum.
#[test]
fn pjrt_best_period_near_closed_form() {
    let Some(rt) = runtime_or_skip() else { return };
    let sc = Scenario::paper(
        1 << 16,
        1.0,
        PredictorSpec::paper_a(600.0),
        Law::Exponential,
        Law::Exponential,
    );
    let lo: f64 = 700.0;
    let hi: f64 = 80_000.0;
    let grid: Vec<f64> = (0..512)
        .map(|k| lo * (hi / lo).powf(k as f64 / 511.0))
        .collect();
    let best = rt.best_periods(&sc, &grid).expect("best_periods");
    let expect = [
        ckptwin::model::optimal::rfo_period(&sc.platform),
        ckptwin::model::optimal::tr_extr_instant(&sc),
        ckptwin::model::optimal::tr_extr_window(&sc),
        ckptwin::model::optimal::tr_extr_window(&sc),
    ];
    for (i, ((tr, _), want)) in best.iter().zip(expect).enumerate() {
        let rel = (tr - want).abs() / want;
        assert!(rel < 0.05, "strategy {i}: grid argmin {tr} vs formula {want}");
    }
}

/// init -> step -> eval: losses finite, parameters change, training reduces
/// loss on a repetitive corpus; snapshot/restore rewinds exactly.
#[test]
fn train_artifact_learns_and_restores() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut trainer = Trainer::new(&rt, 7).expect("init");
    let m = rt.manifest.clone();

    // Repetitive corpus: "abcdefgh" cycled — quickly learnable.
    let tokens: Vec<i32> = (0..m.batch * m.seq_len)
        .map(|i| (i % 8) as i32 + 97)
        .collect();

    let theta0 = trainer.snapshot();
    let l0 = trainer.eval(&tokens).expect("eval");
    assert!(l0.is_finite() && l0 > 0.0);

    let mut losses = Vec::new();
    for _ in 0..30 {
        losses.push(trainer.step(&tokens, 0.1).expect("step"));
    }
    assert!(losses.iter().all(|l| l.is_finite()));
    let l_end = trainer.eval(&tokens).expect("eval");
    assert!(
        l_end < l0 * 0.7,
        "no learning: {l0} -> {l_end} (losses {losses:?})"
    );
    assert_ne!(theta0, trainer.snapshot());

    // Restore rewinds the model exactly.
    trainer.restore(theta0.clone()).expect("restore");
    let l_restored = trainer.eval(&tokens).expect("eval");
    assert!((l_restored - l0).abs() < 1e-5, "{l_restored} vs {l0}");
}

/// Initialization is seed-deterministic and seeds differ.
#[test]
fn init_params_seeded() {
    let Some(rt) = runtime_or_skip() else { return };
    let a = Trainer::new(&rt, 1).expect("init").snapshot();
    let b = Trainer::new(&rt, 1).expect("init").snapshot();
    let c = Trainer::new(&rt, 2).expect("init").snapshot();
    assert_eq!(a, b);
    assert_ne!(a, c);
    assert_eq!(a.len(), rt.manifest.param_count);
}
