//! Coordinator end-to-end tests: the full checkpoint/restore/recovery loop
//! against both the synthetic workload (always) and the PJRT transformer
//! workload (when artifacts are built).

use ckptwin::config::{FaultModel, Platform, PredictorSpec, Scenario};
use ckptwin::coordinator::workload::{PjrtWorkload, SyntheticWorkload};
use ckptwin::coordinator::{self, CoordinatorConfig};
use ckptwin::runtime::Runtime;
use ckptwin::sim::distribution::Law;
use ckptwin::strategy::{Policy, PolicyKind};

fn config(tag: &str, mu: f64, kind: PolicyKind, steps: u64) -> CoordinatorConfig {
    let scenario = Scenario {
        platform: Platform { mu, c: 120.0, cp: 60.0, d: 30.0, r: 60.0 },
        predictor: PredictorSpec::paper(0.85, 0.82, 240.0),
        fault_law: Law::Exponential,
        false_pred_law: Law::Exponential,
        fault_model: FaultModel::PlatformRenewal,
        job_size: 0.0,
    };
    let dir = std::env::temp_dir().join(format!(
        "ckptwin-e2e-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    CoordinatorConfig {
        scenario,
        policy: Policy { kind, tr: 1000.0, tp: 200.0 },
        seconds_per_step: 25.0,
        total_steps: steps,
        ckpt_dir: dir,
        seed: 7,
        log_every: 5,
        selfckpt: None,
    }
}

/// Waste measured by the coordinator approaches the analytic prediction
/// for a long fault-free run (pure checkpoint overhead).
#[test]
fn coordinator_waste_matches_overhead_fault_free() {
    let cfg = config("overhead", 1e13, PolicyKind::IgnorePredictions, 600);
    let mut w = SyntheticWorkload::new(32);
    let rep = coordinator::run(&cfg, &mut w).unwrap();
    // Period: work (1000-120)/25 = 35.2 -> 35 steps = 875 s + 120 s ckpt.
    // waste ≈ 120 / 995.
    let expect = 120.0 / (35.0 * 25.0 + 120.0);
    assert!(
        (rep.sim_waste - expect).abs() < 0.02,
        "waste {} vs {expect}",
        rep.sim_waste
    );
}

/// Under heavy fault injection the coordinator still completes, and every
/// fault triggers exactly one recovery from a *durable* checkpoint.
#[test]
fn coordinator_survives_heavy_faults() {
    let cfg = config("heavy", 1500.0, PolicyKind::WithCkpt, 300);
    let mut w = SyntheticWorkload::new(32);
    let rep = coordinator::run(&cfg, &mut w).unwrap();
    assert!(rep.n_faults >= 3, "expected several faults, got {}", rep.n_faults);
    assert_eq!(rep.n_recoveries, rep.n_faults);
    assert!(rep.steps_executed >= 300);
    assert_eq!(rep.losses.last().unwrap().0, 300);
    // Re-executed (lost) steps are consistent with the executed total.
    assert!(rep.steps_executed as i64 - 300 >= rep.steps_lost as i64 - 5);
}

/// The prediction-aware coordinator takes proactive checkpoints and loses
/// no more work than the prediction-ignoring one on the same trace.
#[test]
fn prediction_aware_coordinator_loses_less() {
    let aware = {
        let cfg = config("aw", 2500.0, PolicyKind::WithCkpt, 300);
        coordinator::run(&cfg, &mut SyntheticWorkload::new(16)).unwrap()
    };
    let ignore = {
        let cfg = config("ig", 2500.0, PolicyKind::IgnorePredictions, 300);
        coordinator::run(&cfg, &mut SyntheticWorkload::new(16)).unwrap()
    };
    assert!(aware.n_pro_ckpts > 0);
    // Same fault trace (same seed & scenario): trusting an accurate
    // predictor must not lose substantially more work.
    assert!(
        aware.steps_lost <= ignore.steps_lost + 20,
        "aware lost {} vs ignore {}",
        aware.steps_lost,
        ignore.steps_lost
    );
}

/// Full-stack e2e: the PJRT transformer under fault injection — loss
/// decreases despite recoveries.  Skips when artifacts are missing.
#[test]
fn pjrt_training_under_faults_learns() {
    if !Runtime::artifacts_present() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let rt = Runtime::discover().expect("runtime");
    let cfg = config("pjrt", 2500.0, PolicyKind::WithCkpt, 120);
    let mut w = PjrtWorkload::new(&rt, cfg.seed, 0.1).expect("workload");
    let rep = coordinator::run(&cfg, &mut w).expect("run");
    assert_eq!(rep.losses.last().unwrap().0, 120);
    let first = rep.losses.first().unwrap().1;
    let last = rep.losses.last().unwrap().1;
    assert!(
        last < first,
        "no learning under faults: {first} -> {last} ({} faults)",
        rep.n_faults
    );
}
