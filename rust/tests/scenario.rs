//! Scenario-language integration tests: the committed `.ckpt` suites
//! under `scenarios/` compile to pinned cell counts / keys / hashes, a
//! scenario file expands byte-identically to the equivalent CLI-flag
//! invocation, `replay` reproduces stored campaign and conformance
//! records field for field, and `explain` re-derives sweep verdicts
//! bit-for-bit with the 5 tolerance terms summing to the priced
//! tolerance.

use std::collections::HashMap;
use std::path::PathBuf;

use ckptwin::campaign::{self, grid::fnv1a64, overrides, CampaignOptions, Grid, Store};
use ckptwin::harness::figures;
use ckptwin::model::waste::Inapplicability as M;
use ckptwin::scenario::ast::ScenarioFile;
use ckptwin::scenario::compile::{compile_str, SuiteKind};
use ckptwin::scenario::explain::{explain_cell, guard_sentence};
use ckptwin::scenario::lint_str;
use ckptwin::scenario::replay::{
    diff_campaign, diff_conformance, replay_campaign, replay_conformance,
    sniff_store_kind, StoreKind,
};
use ckptwin::validate::{
    self, CellReport, ConformanceStore, Inapplicable, SweepOptions, TolerancePolicy,
    ValCell, Verdict,
};

fn scenario_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../scenarios")
}

fn read_suite(name: &str) -> String {
    let path = scenario_dir().join(name);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("cannot read {}: {e}", path.display()))
}

fn tmp(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!(
        "ckptwin-scenario-{tag}-{}.jsonl",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&p);
    p
}

fn assert_bits(a: f64, b: f64, what: &str, key: &str) {
    let same = a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan());
    assert!(same, "{what} differs at {key}: {a:?} vs {b:?}");
}

/// Every committed suite compiles; kind, cell count, first-cell key and
/// scenario hash are pinned as literals so any drift in the key grammar
/// or grid-expansion order breaks here with a readable diff.
#[test]
fn committed_suites_compile_to_pinned_counts_and_keys() {
    struct Pin {
        file: &'static str,
        kind: SuiteKind,
        cells: usize,
        first_key: &'static str,
    }
    let pins = [
        Pin {
            file: "paper.ckpt",
            kind: SuiteKind::Campaign,
            cells: 1200,
            first_key: "procs=65536;cp=1;law=exponential;fp=exponential;scale=1;\
                        p=0.82;r=0.85;I=300;strat=Daly",
        },
        Pin {
            file: "fig5.ckpt",
            kind: SuiteKind::Campaign,
            cells: 300,
            first_key: "procs=65536;cp=1;law=exponential;fp=exponential;scale=1;\
                        p=0.4;r=0.7;I=300;strat=Daly",
        },
        Pin {
            file: "fig6.ckpt",
            kind: SuiteKind::Campaign,
            cells: 300,
            first_key: "procs=65536;cp=0.1;law=exponential;fp=exponential;scale=1;\
                        p=0.4;r=0.7;I=300;strat=Daly",
        },
        Pin {
            file: "smoke.ckpt",
            kind: SuiteKind::Campaign,
            cells: 16,
            first_key: "procs=65536;cp=1;law=exponential;fp=exponential;scale=0.05;\
                        p=0.82;r=0.85;I=600;strat=RFO",
        },
        Pin {
            file: "census72.ckpt",
            kind: SuiteKind::Conformance,
            cells: 72,
            first_key: "procs=65536;cp=1;law=exponential;fp=exponential;scale=0.2;\
                        p=0.82;r=0.85;I=600;strat=Daly;fm=platform;m=1",
        },
    ];
    for pin in &pins {
        let suite = compile_str(&read_suite(pin.file))
            .unwrap_or_else(|e| panic!("{}: {e}", pin.file));
        assert_eq!(suite.kind, pin.kind, "{}", pin.file);
        assert_eq!(suite.cell_count(), pin.cells, "{}", pin.file);
        let want = pin.first_key.replace(char::is_whitespace, "");
        match suite.kind {
            SuiteKind::Campaign => {
                let cells = suite.cells();
                assert_eq!(cells.len(), pin.cells, "{}", pin.file);
                assert_eq!(cells[0].key(), want, "{}", pin.file);
                assert_eq!(cells[0].hash, fnv1a64(want.as_bytes()), "{}", pin.file);
            }
            SuiteKind::Conformance => {
                let cells = suite.val_cells();
                assert_eq!(cells.len(), pin.cells, "{}", pin.file);
                assert_eq!(cells[0].key(), want, "{}", pin.file);
                assert_eq!(cells[0].hash, fnv1a64(want.as_bytes()), "{}", pin.file);
            }
        }
    }
}

/// The committed figure suites are *exactly* what the harness emitter
/// renders for the matching spec — the files are generated artifacts,
/// re-derivable, never hand-drifted.
#[test]
fn fig_suites_match_harness_emitter_byte_for_byte() {
    for id in [5u8, 6] {
        let spec = figures::waste_vs_n_specs()
            .into_iter()
            .find(|s| s.id == id)
            .unwrap_or_else(|| panic!("no waste-vs-N spec with id {id}"));
        let emitted = figures::waste_vs_n_scenario(&spec);
        let committed = read_suite(&format!("fig{id}.ckpt"));
        assert_eq!(committed, emitted, "scenarios/fig{id}.ckpt drifted from emitter");
    }
}

/// A `.ckpt` file and the equivalent CLI-flag invocation compile to the
/// same grid: same keys, same scenario hashes, same paired seeds. This
/// is the language's core contract — a scenario file is never a third
/// dialect, it funnels through the same `overrides::apply_override`.
#[test]
fn scenario_file_and_cli_flags_expand_identically() {
    // fig5.ckpt == `campaign run --grid paper --cp-ratios 1 --predictors b`.
    let suite = compile_str(&read_suite("fig5.ckpt")).unwrap();
    let mut flags = Grid::paper();
    overrides::apply_override(&mut flags, "cp-ratios", "1").unwrap();
    overrides::apply_override(&mut flags, "predictors", "b").unwrap();
    let (a, b) = (suite.cells(), flags.expand());
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.key(), y.key());
        assert_eq!(x.hash, y.hash);
        assert_eq!(x.instance_seed(7), y.instance_seed(7));
    }

    // smoke.ckpt spells every axis explicitly yet lands on Grid::smoke().
    let suite = compile_str(&read_suite("smoke.ckpt")).unwrap();
    let (a, b) = (suite.cells(), Grid::smoke().expand());
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.key(), y.key());
        assert_eq!(x.hash, y.hash);
        assert_eq!(x.instance_seed(7), y.instance_seed(7));
    }
}

/// parse -> render is a fixpoint on every committed file, and the
/// generated figure files are already in canonical form (byte-equal to
/// their own render).
#[test]
fn committed_files_render_canonically() {
    for file in ["paper.ckpt", "fig5.ckpt", "fig6.ckpt", "smoke.ckpt", "census72.ckpt"] {
        let raw = read_suite(file);
        let once = ScenarioFile::parse(&raw).unwrap_or_else(|e| panic!("{file}: {e}"));
        let rendered = once.render();
        let again = ScenarioFile::parse(&rendered).unwrap().render();
        assert_eq!(rendered, again, "{file}: render not a fixpoint");
    }
    // The emitter writes canonical form directly (no comments), so for
    // the generated files raw == render exactly.
    for file in ["fig5.ckpt", "fig6.ckpt"] {
        let raw = read_suite(file);
        assert_eq!(raw, ScenarioFile::parse(&raw).unwrap().render(), "{file}");
    }
}

/// `ckptwin lint` is clean over every committed suite; the conformance
/// census additionally warns about its known-classified cells (reported,
/// never silently dropped).
#[test]
fn committed_suites_lint_clean() {
    for file in ["paper.ckpt", "fig5.ckpt", "fig6.ckpt", "smoke.ckpt", "census72.ckpt"] {
        let rep = lint_str(&read_suite(file));
        assert!(
            rep.errors.is_empty(),
            "{file}: unexpected lint errors: {:?}",
            rep.errors
        );
        assert!(rep.name.is_some(), "{file}: no suite name");
    }
    let census = lint_str(&read_suite("census72.ckpt"));
    assert_eq!(census.cells, 72);
    assert!(
        census.warnings.iter().any(|d| d.msg.contains("no_closed_form")),
        "census72 should pre-classify its no-closed-form cells: {:?}",
        census.warnings
    );
}

/// Replay a freshly written campaign store: every record re-runs to a
/// field-for-field identical record (the `replay --verify` contract).
#[test]
fn replay_reproduces_campaign_store() {
    let mut g = Grid::smoke();
    overrides::apply_override(&mut g, "procs", "65536").unwrap();
    overrides::apply_override(&mut g, "windows", "600").unwrap();
    let cells = g.expand();
    assert_eq!(cells.len(), 4);

    let path = tmp("replay-campaign");
    let mut store = Store::create(&path).unwrap();
    let opt = CampaignOptions { instances: 3, ..Default::default() };
    let (outcomes, _) = campaign::run_cells(&cells, &opt, Some(&mut store)).unwrap();
    assert_eq!(outcomes.len(), 4);
    drop(store);

    assert_eq!(sniff_store_kind(&path).unwrap(), StoreKind::Campaign);
    let store = Store::open(&path).unwrap();
    assert_eq!(store.len(), 4);
    for rec in store.records() {
        let fresh = replay_campaign(rec).unwrap();
        let diffs = diff_campaign(rec, &fresh);
        assert!(diffs.is_empty(), "{}: replay diverged: {diffs:?}", rec.key);
    }
    let _ = std::fs::remove_file(&path);
}

/// Replay a freshly written conformance store — pass and inapplicable
/// verdicts both reproduce exactly (NaN fields compare NaN-aware).
#[test]
fn replay_reproduces_conformance_store() {
    let cells: Vec<ValCell> = validate::expand_cells(&validate::smoke_grid(), &[1.0])
        .into_iter()
        .take(10)
        .collect();
    let path = tmp("replay-conformance");
    let mut store = ConformanceStore::create(&path).unwrap();
    let opt = SweepOptions { instances: 4, ..Default::default() };
    let (reports, _) = validate::run_sweep(&cells, &opt, Some(&mut store)).unwrap();
    assert_eq!(reports.len(), cells.len());
    drop(store);

    assert_eq!(sniff_store_kind(&path).unwrap(), StoreKind::Conformance);
    let store = ConformanceStore::open(&path).unwrap();
    assert_eq!(store.len(), cells.len());
    let mut verdicts = HashMap::<String, usize>::new();
    for rec in store.records() {
        *verdicts.entry(rec.verdict.clone()).or_insert(0) += 1;
        let fresh = replay_conformance(rec).unwrap();
        let diffs = diff_conformance(rec, &fresh);
        assert!(diffs.is_empty(), "{}: replay diverged: {diffs:?}", rec.key);
    }
    // The first 10 smoke-grid cells span both verdict families.
    assert!(verdicts.contains_key("pass"), "{verdicts:?}");
    assert!(verdicts.contains_key("inapplicable"), "{verdicts:?}");
    let _ = std::fs::remove_file(&path);
}

/// `explain` re-derives exactly what a sweep computes: same verdict,
/// same statistics bit-for-bit, and the 5 tolerance terms sum — in
/// order — to the priced tolerance, also bit-for-bit.
#[test]
fn explain_matches_sweep_bit_for_bit() {
    let cells: Vec<ValCell> = validate::expand_cells(&validate::smoke_grid(), &[1.0])
        .into_iter()
        .take(12)
        .collect();
    let opt = SweepOptions { instances: 6, ..Default::default() };
    let (reports, _) = validate::run_sweep(&cells, &opt, None).unwrap();
    let by_hash: HashMap<u64, &CellReport> =
        reports.iter().map(|r| (r.hash, r)).collect();

    let policy = TolerancePolicy::default();
    let mut compared = 0usize;
    for vc in &cells {
        let ex = explain_cell(vc, 6, &policy);
        let r = by_hash[&vc.hash];
        assert_eq!(ex.key, r.key);
        assert_eq!(ex.verdict.label(), r.verdict.label(), "{}", r.key);
        assert_eq!(ex.instances, r.instances, "{}", r.key);
        assert_bits(ex.tr, r.tr, "tr", &r.key);
        assert_bits(ex.sim_mean, r.sim_mean, "sim_mean", &r.key);
        assert_bits(ex.sim_ci95, r.sim_ci95, "sim_ci95", &r.key);
        assert_bits(ex.model, r.model, "model", &r.key);
        assert_bits(ex.deviation, r.deviation, "deviation", &r.key);
        assert_bits(ex.tolerance, r.tolerance, "tolerance", &r.key);
        if matches!(ex.verdict, Verdict::Pass | Verdict::Fail) {
            assert_eq!(ex.terms.len(), 5, "{}", r.key);
            let sum = ex.terms.iter().fold(0.0f64, |a, t| a + t.value);
            assert_bits(sum, ex.tolerance, "terms-sum", &r.key);
            compared += 1;
        } else {
            assert!(ex.terms.is_empty(), "{}", r.key);
            assert!(ex.guard.is_some(), "{}", r.key);
        }
    }
    assert!(compared >= 2, "too few applicable cells to pin the term sum");
}

/// Every `Inapplicable` variant renders a guard sentence carrying its
/// stable label (or, for NoClosedForm, the prose marker) — and the
/// sentence is deterministic.
#[test]
fn guard_sentences_cover_every_variant() {
    let cells = validate::expand_cells(&validate::smoke_grid(), &[1.0]);
    let vc = &cells[0];
    let sc = vc.scenario();
    let kind = vc.cell.strategy.kind();
    let policy = TolerancePolicy::default();
    let variants: [(Inapplicable, &str); 15] = [
        (Inapplicable::Model(M::PeriodWithinCheckpoint), "period_within_checkpoint"),
        (Inapplicable::Model(M::MtbfWithinRecovery), "mtbf_within_recovery"),
        (Inapplicable::Model(M::ZeroPrecision), "zero_precision"),
        (
            Inapplicable::Model(M::ProactivePeriodOutsideWindow),
            "proactive_period_outside_window",
        ),
        (Inapplicable::Model(M::WasteOutOfRange), "waste_out_of_range"),
        (Inapplicable::NoClosedForm, "no closed form"),
        (Inapplicable::BeyondFirstOrder, "beyond_first_order"),
        (Inapplicable::JobTooShort, "job_too_short"),
        (Inapplicable::WindowsOverlap, "windows_overlap"),
        (Inapplicable::TransientFaultModel, "transient_fault_model"),
        (Inapplicable::HorizonTooShort, "horizon_too_short"),
        (Inapplicable::NonUniformWindow, "non_uniform_window"),
        (Inapplicable::NoisyWindowPlacement, "noisy_window_placement"),
        (Inapplicable::ConfidenceClasses, "confidence_classes"),
        (Inapplicable::PlatformRateNonconforming, "platform_rate_nonconforming"),
    ];
    for (reason, marker) in variants {
        let s = guard_sentence(reason, &sc, kind, 1234.5, 300.0, &policy);
        assert!(s.contains(marker), "{marker}: sentence lacks its label: {s}");
        assert!(s.len() > 40, "{marker}: sentence too terse: {s}");
        let again = guard_sentence(reason, &sc, kind, 1234.5, 300.0, &policy);
        assert_eq!(s, again, "{marker}: non-deterministic sentence");
    }
}

/// Transcript structure: a no-closed-form cell gets a guard line and no
/// simulation section; an applicable cell gets the full tolerance-term
/// breakdown with all five labels plus the total row.
#[test]
fn explain_transcript_structure() {
    let cells = validate::expand_cells(&validate::smoke_grid(), &[1.0]);
    let policy = TolerancePolicy::default();

    let ncf = cells
        .iter()
        .find(|vc| vc.cell.strategy.to_string() == "ExactPred")
        .expect("smoke grid carries ExactPred");
    let ex = explain_cell(ncf, 4, &policy);
    let out = ex.render();
    assert!(out.starts_with(&format!("cell      {}\n", ncf.key())), "{out}");
    assert!(out.contains("verdict   inapplicable"), "{out}");
    assert!(out.contains("guard: "), "{out}");
    assert!(out.contains("no closed form"), "{out}");
    assert!(!out.contains("period T_R"), "NoClosedForm has no period: {out}");

    let daly = cells
        .iter()
        .find(|vc| {
            vc.cell.strategy.to_string() == "Daly"
                && matches!(explain_cell(vc, 4, &policy).verdict, Verdict::Pass)
        })
        .expect("smoke grid carries a passing Daly cell");
    let ex = explain_cell(daly, 4, &policy);
    assert!(ex.guard.is_none());
    let out = ex.render();
    assert!(out.contains("verdict   pass"), "{out}");
    assert!(out.contains("tolerance terms:"), "{out}");
    for label in
        ["abs_floor", "tail_spread", "curvature", "renewal_excess", "sampling_ci", "total"]
    {
        assert!(out.contains(label), "missing term {label} in:\n{out}");
    }
}
