//! Tier-1 conformance gate: a small deterministic model-vs-simulation
//! sweep (exponential + Weibull, every registered strategy) asserting the
//! ISSUE's acceptance bar — every applicable (strategy, law, predictor)
//! cell within its declared tolerance or explicitly classified
//! `Inapplicable`, zero unexplained failures.
//!
//! The CLI runs the same machinery over larger grids (`ckptwin validate`);
//! this file pins a fixed subset so any model/engine/policy drift breaks
//! the build, not just the artifact.

use ckptwin::campaign::Grid;
use ckptwin::strategy::registry;
use ckptwin::validate::{
    self, domain, expand_cells, CellReport, ConformanceStore, Inapplicable,
    SweepOptions, Verdict,
};

/// The gate's grid: both paper fault-law families, both C_p ratios, two
/// window sizes, every registered strategy except the BestPeriod twins
/// (checked separately below — their instantiation is a search).
fn gate_grid() -> Grid {
    validate::smoke_grid()
}

fn run_gate(instances: usize, multipliers: &[f64]) -> Vec<CellReport> {
    let cells = expand_cells(&gate_grid(), multipliers);
    let opt = SweepOptions { instances, ..Default::default() };
    let (reports, skipped) = validate::run_sweep(&cells, &opt, None).unwrap();
    assert_eq!(skipped, 0);
    assert_eq!(reports.len(), cells.len());
    reports
}

#[test]
fn every_cell_passes_or_is_classified() {
    let reports = run_gate(32, &[1.0]);
    let mut pass = 0;
    let mut inapplicable = 0;
    for r in &reports {
        match r.verdict {
            Verdict::Pass => {
                pass += 1;
                assert!(r.deviation <= r.tolerance, "{}", r.key);
                assert!(r.model > 0.0 && r.model < 1.0, "{}", r.key);
                assert!(r.sim_ci95 >= 0.0 && r.sim_mean > 0.0, "{}", r.key);
            }
            Verdict::Fail => panic!(
                "unexplained conformance failure at {}:\n  sim {:.4} ±{:.4} vs \
                 model {:.4} — |dev| {:.4} > tol {:.4}",
                r.key, r.sim_mean, r.sim_ci95, r.model, r.deviation, r.tolerance
            ),
            Verdict::Inapplicable(reason) => {
                inapplicable += 1;
                // Every classification must be one the gate grid explains:
                // strategies without closed forms, and WithCkptI cells
                // whose window cannot hold the proactive period.
                match reason {
                    Inapplicable::NoClosedForm => assert!(
                        ["ExactPred", "WindowEndCkpt"].contains(&r.strategy.as_str())
                            || r.strategy.starts_with("QTrust"),
                        "{}: unexpected no_closed_form",
                        r.key
                    ),
                    Inapplicable::Model(
                        ckptwin::model::waste::Inapplicability::ProactivePeriodOutsideWindow,
                    ) => {
                        assert_eq!(r.strategy, "WithCkptI", "{}", r.key);
                    }
                    other => panic!("{}: unexpected classification {other}", r.key),
                }
            }
        }
    }
    // The gate grid has 8 scenario points × 9 strategies.  Applicable:
    // 3 q=0 strategies + Instant + NoCkptI everywhere (40 cells), and
    // WithCkptI wherever T_P fits the window (6 of 8).
    assert_eq!(reports.len(), 72);
    assert_eq!(pass, 46, "applicable-cell census drifted");
    assert_eq!(inapplicable, 26);
    // Both fault laws are actually compared, not classified away.
    for law in ["exponential", "weibull0.7"] {
        assert!(
            reports
                .iter()
                .any(|r| r.law == law && matches!(r.verdict, Verdict::Pass)),
            "no passing {law} cell"
        );
    }
}

#[test]
fn off_optimal_periods_also_conform() {
    // Sweep the formulas off their optimum: Eqs. (3)/(10)/(14) are curves
    // in T_R, not just optimal points.  Restricted to the q=0 strategies +
    // NoCkptI on the exponential law to keep tier-1 fast.
    let mut grid = gate_grid();
    grid.fault_laws = vec![ckptwin::sim::distribution::Law::Exponential];
    grid.cp_ratios = vec![1.0];
    grid.windows = vec![600.0];
    grid.strategies = vec![
        registry::get("Daly").unwrap(),
        registry::get("RFO").unwrap(),
        registry::get("NoCkptI").unwrap(),
    ];
    let cells = expand_cells(&grid, &[0.7, 1.0, 1.4]);
    let opt = SweepOptions { instances: 32, ..Default::default() };
    let (reports, _) = validate::run_sweep(&cells, &opt, None).unwrap();
    assert_eq!(reports.len(), 9);
    for r in &reports {
        assert_eq!(
            r.verdict,
            Verdict::Pass,
            "{}: sim {:.4} vs model {:.4}, |dev| {:.4} > tol {:.4}",
            r.key,
            r.sim_mean,
            r.model,
            r.deviation,
            r.tolerance
        );
    }
    // The multiplier axis really probes distinct periods, and the model
    // follows the simulation away from the optimum (waste rises off-opt).
    let daly: Vec<&CellReport> =
        reports.iter().filter(|r| r.strategy == "Daly").collect();
    assert_eq!(daly.len(), 3);
    assert!(daly[0].tr < daly[1].tr && daly[1].tr < daly[2].tr);
    assert!(daly[0].model > daly[1].model || daly[2].model > daly[1].model);
}

#[test]
fn best_period_twin_conforms_at_its_searched_period() {
    // A BestPeriod twin has no closed form *rule*, but its searched period
    // is still a point on Eq. (3)'s curve: the comparison must hold there
    // too (search seeds are disjoint from evaluation seeds, so there is no
    // selection bias).
    let mut grid = gate_grid();
    grid.fault_laws = vec![ckptwin::sim::distribution::Law::Exponential];
    grid.cp_ratios = vec![1.0];
    grid.windows = vec![600.0];
    grid.strategies =
        vec![registry::StrategyId::parse("BestPeriod-NoPred(seeds=4)").unwrap()];
    let cells = expand_cells(&grid, &[1.0]);
    let opt = SweepOptions { instances: 24, ..Default::default() };
    let (reports, _) = validate::run_sweep(&cells, &opt, None).unwrap();
    assert_eq!(reports.len(), 1);
    let r = &reports[0];
    assert_eq!(
        r.verdict,
        Verdict::Pass,
        "{}: |dev| {:.4} > tol {:.4}",
        r.key,
        r.deviation,
        r.tolerance
    );
    assert!(r.tr > 0.0 && r.tr.is_finite());
}

#[test]
fn gate_is_deterministic_across_runs_and_threads() {
    let a = run_gate(10, &[1.0]);
    let b = run_gate(10, &[1.0]);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.key, y.key);
        assert_eq!(x.verdict, y.verdict, "{}", x.key);
        assert_eq!(x.sim_mean.to_bits(), y.sim_mean.to_bits(), "{}", x.key);
        assert_eq!(x.deviation.to_bits(), y.deviation.to_bits(), "{}", x.key);
    }
    // And single-threaded agrees bit-for-bit with the pool.
    let cells = expand_cells(&gate_grid(), &[1.0]);
    let serial = validate::run_sweep(
        &cells,
        &SweepOptions { instances: 10, threads: 1, ..Default::default() },
        None,
    )
    .unwrap()
    .0;
    for (x, y) in a.iter().zip(&serial) {
        assert_eq!(x.sim_mean.to_bits(), y.sim_mean.to_bits(), "{}", x.key);
    }
}

#[test]
fn conformance_store_resumes_and_artifact_is_valid_json() {
    let dir = std::env::temp_dir()
        .join(format!("ckptwin-conformance-gate-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let store_path = dir.join("conformance.jsonl");
    let json_path = dir.join("CONFORMANCE.json");

    let mut grid = gate_grid();
    grid.fault_laws = vec![ckptwin::sim::distribution::Law::Exponential];
    grid.windows = vec![600.0];
    let cells = expand_cells(&grid, &[1.0]);
    let opt = SweepOptions { instances: 8, ..Default::default() };
    {
        let mut store = ConformanceStore::create(&store_path).unwrap();
        let (fresh, _) = validate::run_sweep(&cells, &opt, Some(&mut store)).unwrap();
        assert_eq!(fresh.len(), cells.len());
        assert_eq!(store.len(), cells.len());
    }
    // Resume: nothing recomputed, reports reconstructable from disk.
    let mut store = ConformanceStore::open(&store_path).unwrap();
    let (fresh, skipped) = validate::run_sweep(&cells, &opt, Some(&mut store)).unwrap();
    assert!(fresh.is_empty());
    assert_eq!(skipped, cells.len());
    let reports: Vec<CellReport> = cells
        .iter()
        .map(|vc| CellReport::from_record(store.get(vc.hash).unwrap()).unwrap())
        .collect();
    // The artifact round-trips through the strict JSON parser.
    let summaries = validate::summarize(&reports);
    validate::write_json(&json_path, &reports, &summaries).unwrap();
    let text = std::fs::read_to_string(&json_path).unwrap();
    let doc = ckptwin::jsonio::parse(&text).expect("CONFORMANCE.json is valid JSON");
    assert_eq!(
        doc.get("schema").and_then(ckptwin::jsonio::Value::as_str),
        Some("ckptwin-conformance/1")
    );
    let total = doc.get("summary").unwrap().get("cells").unwrap().as_usize();
    assert_eq!(total, Some(cells.len()));
    assert_eq!(
        doc.get("summary").unwrap().get("fail").unwrap().as_usize(),
        Some(0),
        "gate sweep must have zero failures in the artifact too"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Predictor-axis census, pinned: a grid over all five registered
/// predictor models.  The `biased` model must PASS the prediction-aware
/// comparisons — the closed forms priced at its per-model E_I^f (the
/// tentpole's E_I^f dataflow, checked end-to-end against the simulator) —
/// while `mixedwin`/`jitter`/`classed` classify under their named reasons
/// and the q = 0 formula (predictor-blind) passes everywhere.
#[test]
fn predictor_model_census_is_pinned() {
    use ckptwin::predictor::registry as predictors;
    let grid = Grid {
        procs: vec![1 << 16],
        cp_ratios: vec![1.0],
        fault_laws: vec![ckptwin::sim::distribution::Law::Exponential],
        uniform_false_preds: false,
        predictors: vec![
            predictors::get("a").unwrap(),
            predictors::PredictorId::parse("biased(beta=2)").unwrap(),
            predictors::get("mixedwin").unwrap(),
            predictors::get("jitter").unwrap(),
            predictors::get("classed").unwrap(),
        ],
        windows: vec![1200.0],
        strategies: vec![
            registry::get("RFO").unwrap(),
            registry::get("Instant").unwrap(),
            registry::get("NoCkptI").unwrap(),
            registry::get("WithCkptI").unwrap(),
        ],
        scale: 0.25,
        platform_shards: vec![1],
    };
    let cells = expand_cells(&grid, &[1.0]);
    assert_eq!(cells.len(), 20);
    let opt = SweepOptions { instances: 32, ..Default::default() };
    let (reports, _) = validate::run_sweep(&cells, &opt, None).unwrap();
    let (mut pass, mut inapplicable) = (0, 0);
    for r in &reports {
        let model_of = |key: &str| {
            ["mixedwin", "jitter(", "classed"]
                .iter()
                .find(|m| key.contains(*m))
                .copied()
        };
        match r.verdict {
            Verdict::Pass => {
                pass += 1;
                // Only q = 0 cells pass for the formula-breaking models.
                if let Some(m) = model_of(&r.key) {
                    assert_eq!(r.strategy, "RFO", "{m}: {}", r.key);
                }
            }
            Verdict::Fail => panic!(
                "unexplained failure at {}: sim {:.4} vs model {:.4}, \
                 |dev| {:.4} > tol {:.4}",
                r.key, r.sim_mean, r.model, r.deviation, r.tolerance
            ),
            Verdict::Inapplicable(reason) => {
                inapplicable += 1;
                let expected = match model_of(&r.key) {
                    Some("mixedwin") => Inapplicable::NonUniformWindow,
                    Some("jitter(") => Inapplicable::NoisyWindowPlacement,
                    Some("classed") => Inapplicable::ConfidenceClasses,
                    _ => panic!("{}: unexpected classification {reason}", r.key),
                };
                assert_eq!(reason, expected, "{}", r.key);
                assert_ne!(r.strategy, "RFO", "{}", r.key);
            }
        }
    }
    // 4 paper-a passes + 4 biased passes + 3 × (1 q=0 pass).
    assert_eq!(pass, 11, "predictor-axis census drifted");
    assert_eq!(inapplicable, 9);
    // The biased cells really were compared (not classified away).
    assert!(reports
        .iter()
        .any(|r| r.key.contains("biased") && r.verdict == Verdict::Pass
            && r.strategy == "NoCkptI"));
}

#[test]
fn tolerance_policy_has_teeth() {
    // The oracle is not vacuous: a deliberately wrong "model" value at a
    // typical cell exceeds the declared tolerance.  (Guards against the
    // tolerance growing until everything passes.)
    let grid = gate_grid();
    let cells = expand_cells(&grid, &[1.0]);
    let rfo_cell = cells
        .iter()
        .find(|c| c.cell.strategy.name() == "RFO" && c.cell.fault_law.label() == "exponential")
        .unwrap();
    let sc = rfo_cell.scenario();
    let pol = rfo_cell.cell.strategy.policy(&sc);
    let tol_policy = domain::TolerancePolicy::default();
    let model = domain::classify(
        &sc,
        ckptwin::strategy::PolicyKind::IgnorePredictions,
        pol.tr,
        pol.tp,
        &tol_policy,
    )
    .expect("RFO/exponential is in-domain");
    // A 2× model error must NOT fit the tolerance even with a generous CI.
    let tol = domain::tolerance(
        &tol_policy,
        &sc,
        ckptwin::strategy::PolicyKind::IgnorePredictions,
        pol.tr,
        0.01,
    );
    assert!(
        model > 2.0 * tol,
        "tolerance {tol} is vacuous against model waste {model}"
    );
}
