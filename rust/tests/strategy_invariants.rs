//! Generic strategy invariants: every strategy in the registry — present
//! and future — is automatically checked for work conservation, timeline
//! tiling, waste bounds and determinism.  A new registration gets this
//! coverage for free because the suite iterates `registry::all_defaults()`.
//!
//! The second half pins the three new prediction-handling strategies
//! against hand-computed executions on a scripted event stream, and proves
//! `QTrust(q)` bit-identical to the legacy `simulate_q` side door.

use ckptwin::config::{FaultModel, Platform, PredictorSpec, Scenario};
use ckptwin::sim::distribution::Law;
use ckptwin::sim::engine::{
    simulate, simulate_from, simulate_q, simulate_traced,
};
use ckptwin::sim::trace::{Event, EventSource, Prediction};
use ckptwin::strategy::{registry, Policy, PolicyKind, StrategyId};

/// A scaled-down paper scenario with both faults and (true + false)
/// predictions present in the traces.
fn invariant_scenario() -> Scenario {
    let mut sc = Scenario::paper(
        1 << 16,
        1.0,
        PredictorSpec::paper_b(900.0),
        Law::Weibull { shape: 0.7 },
        Law::Weibull { shape: 0.7 },
    );
    sc.job_size *= 0.02;
    sc
}

/// Every registered strategy, with the BestPeriod twins dialed down to a
/// cheap search budget so the suite stays fast.
fn all_strategies() -> Vec<StrategyId> {
    registry::all_defaults()
        .into_iter()
        .map(|id| {
            if id.name().starts_with("BestPeriod-") {
                id.with_param("seeds", 4.0).expect("seeds is declared")
            } else {
                id
            }
        })
        .collect()
}

#[test]
fn every_registered_strategy_satisfies_engine_invariants() {
    let sc = invariant_scenario();
    for id in all_strategies() {
        let pol = id.policy(&sc);
        pol.validate(&sc);
        for seed in [1u64, 7] {
            let out = simulate(&sc, &pol, seed);
            let tag = format!("{id}/seed{seed}");
            // Work conservation: the makespan decomposes exactly.
            let accounted = sc.job_size
                + out.time_ckpt
                + out.time_down
                + out.time_idle
                + out.work_lost;
            assert!(
                (out.makespan - accounted).abs() < 1e-6 * out.makespan,
                "{tag}: makespan {} vs accounted {accounted}",
                out.makespan
            );
            assert!(out.makespan >= sc.job_size, "{tag}");
            // Waste in [0, 1).
            assert!((0.0..1.0).contains(&out.waste()), "{tag}: {}", out.waste());
            // Checkpoint accounting: counts × durations tile time_ckpt.
            let expect = out.n_reg_ckpts as f64 * sc.platform.c
                + out.n_pro_ckpts as f64 * sc.platform.cp;
            assert!(
                (out.time_ckpt - expect).abs() < 1e-6 * expect.max(1.0),
                "{tag}: ckpt time {} vs counts {expect}",
                out.time_ckpt
            );
            // Determinism per (strategy, seed).
            let again = simulate(&sc, &pol, seed);
            assert_eq!(out, again, "{tag}: nondeterministic");
        }
    }
}

#[test]
fn every_registered_strategy_tiles_its_timeline() {
    let sc = invariant_scenario();
    for id in all_strategies() {
        let pol = id.policy(&sc);
        let (out, tl) = simulate_traced(&sc, &pol, 3);
        let totals = tl
            .validate(out.makespan)
            .unwrap_or_else(|e| panic!("{id}: timeline does not tile: {e}"));
        let work = out.makespan - out.time_ckpt - out.time_down - out.time_idle;
        assert!((totals[0] - work).abs() < 1e-6 * out.makespan, "{id}: work");
        assert!((totals[1] - out.time_ckpt).abs() < 1e-6, "{id}: ckpt");
        assert!((totals[2] - out.time_down).abs() < 1e-6, "{id}: down");
        assert!((totals[3] - out.time_idle).abs() < 1e-6, "{id}: idle");
        assert_eq!(tl.faults.len() as u64, out.n_faults, "{id}: faults");
    }
}

/// `QTrust(q)` as a first-class strategy is bit-identical to the legacy
/// `simulate_q` side door running NoCkpt with the same q: the same trust
/// coin-flip stream, the same trace, the same outcome.
#[test]
fn qtrust_strategy_matches_simulate_q_side_door() {
    let sc = invariant_scenario();
    for q in [0.0, 0.3, 0.75, 1.0] {
        let id = StrategyId::parse(&format!("qtrust(q={q})")).unwrap();
        let pol = id.policy(&sc);
        assert_eq!(pol.kind, PolicyKind::QTrust { q });
        let legacy = Policy { kind: PolicyKind::NoCkpt, tr: pol.tr, tp: pol.tp };
        for seed in [2u64, 11] {
            let via_strategy = simulate(&sc, &pol, seed);
            let via_side_door = simulate_q(&sc, &legacy, q, seed);
            assert_eq!(
                via_strategy, via_side_door,
                "q={q} seed={seed}: QTrust diverged from simulate_q"
            );
        }
    }
}

/// The same conservation/accounting/tiling/determinism suite over every
/// predictor in `predictor::registry` — the predictor axis gets the
/// engine-invariant coverage automatically, exactly like the strategy
/// axis: a new registered model is checked here with no test edits.
/// (The BestPeriod twins are skipped: their execution modes are already
/// covered and their per-(strategy × predictor) searches would dominate
/// tier-1 runtime.)
#[test]
fn every_registry_predictor_satisfies_engine_invariants() {
    let strategies: Vec<StrategyId> = registry::all_defaults()
        .into_iter()
        .filter(|s| !s.name().starts_with("BestPeriod-"))
        .collect();
    for pid in ckptwin::predictor::registry::all_defaults() {
        let mut sc = invariant_scenario();
        sc.predictor = pid.spec(900.0);
        for id in &strategies {
            let pol = id.policy(&sc);
            pol.validate(&sc);
            let seed = 5u64;
            let out = simulate(&sc, &pol, seed);
            let tag = format!("{pid}/{id}");
            // Work conservation.
            let accounted = sc.job_size
                + out.time_ckpt
                + out.time_down
                + out.time_idle
                + out.work_lost;
            assert!(
                (out.makespan - accounted).abs() < 1e-6 * out.makespan,
                "{tag}: makespan {} vs accounted {accounted}",
                out.makespan
            );
            // Waste in [0, 1) and checkpoint accounting.
            assert!((0.0..1.0).contains(&out.waste()), "{tag}: {}", out.waste());
            let expect = out.n_reg_ckpts as f64 * sc.platform.c
                + out.n_pro_ckpts as f64 * sc.platform.cp;
            assert!(
                (out.time_ckpt - expect).abs() < 1e-6 * expect.max(1.0),
                "{tag}: ckpt time {} vs counts {expect}",
                out.time_ckpt
            );
            // Determinism.
            assert_eq!(out, simulate(&sc, &pol, seed), "{tag}: nondeterministic");
            // Timeline tiling (the traced path shares the engine builder,
            // so its outcome must also equal the untraced one).
            let (tout, tl) = simulate_traced(&sc, &pol, seed);
            assert_eq!(tout, out, "{tag}: traced path diverged");
            tl.validate(tout.makespan)
                .unwrap_or_else(|e| panic!("{tag}: timeline does not tile: {e}"));
        }
    }
}

/// With recall 0 there are no predictions at all, so ExactPred and Instant
/// (which differ only in what they do about predictions) must coincide.
#[test]
fn exactpred_equals_instant_without_predictions() {
    let mut sc = invariant_scenario();
    sc.predictor.recall = 0.0;
    let exact = registry::get("ExactPred").unwrap().policy(&sc);
    let instant = registry::get("Instant").unwrap().policy(&sc);
    assert_eq!(exact.tr, instant.tr);
    for seed in [1u64, 4] {
        let a = simulate(&sc, &exact, seed);
        let b = simulate(&sc, &instant, seed);
        assert_eq!(a, b, "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// Scripted-stream goldens: one prediction, no faults, hand-computed
// executions for each prediction-handling behaviour.
// ---------------------------------------------------------------------------

/// Replays a fixed event list, then reports quiet infinity.
struct Scripted {
    events: Vec<Event>,
    next: usize,
}

impl Scripted {
    /// One false-positive prediction: announced at t=1000, window
    /// [1600, 2600] (C_p = 600, I = 1000).
    fn one_prediction() -> Scripted {
        Scripted {
            events: vec![Event::Prediction(Prediction {
                notify_t: 1000.0,
                window_start: 1600.0,
                window_end: 2600.0,
                true_positive: false,
                weight: 1.0,
            })],
            next: 0,
        }
    }
}

impl EventSource for Scripted {
    fn next_event(&mut self) -> Event {
        let ev = self
            .events
            .get(self.next)
            .copied()
            .unwrap_or(Event::Fault { t: f64::INFINITY, predicted: false });
        self.next += 1;
        ev
    }
}

/// C = C_p = 600, job 10000, T_R = 3600 (work 3000), T_P = 1200.
fn scripted_scenario() -> Scenario {
    Scenario {
        platform: Platform { mu: 1e9, c: 600.0, cp: 600.0, d: 60.0, r: 600.0 },
        predictor: PredictorSpec::paper(0.5, 0.5, 1000.0),
        fault_law: Law::Exponential,
        false_pred_law: Law::Exponential,
        fault_model: FaultModel::PlatformRenewal,
        job_size: 10_000.0,
    }
}

fn run_scripted(kind: PolicyKind) -> ckptwin::SimOutcome {
    let sc = scripted_scenario();
    let pol = Policy { kind, tr: 3600.0, tp: 1200.0 };
    simulate_from(&sc, &pol, 1.0, 0, Scripted::one_prediction())
}

#[test]
fn scripted_instant_resumes_interrupted_period() {
    let out = run_scripted(PolicyKind::Instant);
    // Pre-window ckpt at [1000,1600]; the interrupted period (2000 work
    // left) resumes, then three full regular periods finish the job.
    assert_eq!(out.makespan, 12_400.0);
    assert_eq!((out.n_pro_ckpts, out.n_reg_ckpts), (1, 3));
    assert_eq!(out.n_preds_trusted, 1);
}

#[test]
fn scripted_exactpred_starts_fresh_period() {
    let out = run_scripted(PolicyKind::ExactPred);
    // Same pre-window ckpt, but it replaces the period's checkpoint: a
    // fresh 3000-work period starts at 1600, saving one regular
    // checkpoint relative to Instant on this trace.
    assert_eq!(out.makespan, 11_800.0);
    assert_eq!((out.n_pro_ckpts, out.n_reg_ckpts), (1, 2));
    // The outcomes genuinely differ — resumption is the only difference.
    assert_ne!(out.makespan, run_scripted(PolicyKind::Instant).makespan);
}

#[test]
fn scripted_nockpt_works_through_window() {
    let out = run_scripted(PolicyKind::NoCkpt);
    // 1000 s of unprotected in-window work, then the period resumes.
    assert_eq!(out.makespan, 11_800.0);
    assert_eq!((out.n_pro_ckpts, out.n_reg_ckpts), (1, 2));
}

#[test]
fn scripted_windowendckpt_takes_terminal_checkpoint() {
    let out = run_scripted(PolicyKind::WindowEndCkpt);
    // Like NoCkpt, plus a second proactive checkpoint at t0 + I = 2600.
    assert_eq!(out.makespan, 12_400.0);
    assert_eq!((out.n_pro_ckpts, out.n_reg_ckpts), (2, 2));
    // The terminal checkpoint secures the window's work: total checkpoint
    // time is exactly two proactive + two regular checkpoints.
    assert_eq!(out.time_ckpt, 2.0 * 600.0 + 2.0 * 600.0);
}

#[test]
fn scripted_withckpt_checkpoints_inside_window() {
    let out = run_scripted(PolicyKind::WithCkpt);
    // One in-window proactive period (work 600 + ckpt 600 crossing t0+I),
    // then the interrupted period resumes.
    assert_eq!(out.makespan, 13_000.0);
    assert_eq!((out.n_pro_ckpts, out.n_reg_ckpts), (2, 3));
}

#[test]
fn scripted_ignore_mode_drops_the_prediction() {
    let out = run_scripted(PolicyKind::IgnorePredictions);
    assert_eq!(out.makespan, 11_800.0); // 10000 work + 3 regular ckpts
    assert_eq!((out.n_pro_ckpts, out.n_reg_ckpts), (0, 3));
    assert_eq!(out.n_preds_seen, 1);
    assert_eq!(out.n_preds_trusted, 0);
}
