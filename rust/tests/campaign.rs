//! Campaign-engine integration tests: grid expansion, scenario-hash
//! stability, work-stealing determinism, and the resumable store —
//! including the acceptance scenario: a ≥200-cell grid run end-to-end,
//! interrupted, and resumed with only the missing cells recomputed.

use std::path::PathBuf;

use ckptwin::campaign::{self, grid::fnv1a64, CampaignOptions, Grid, Store};
use ckptwin::predictor::registry as predictors;
use ckptwin::sim::distribution::Law;
use ckptwin::strategy::{registry, StrategyId};

fn tmp(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!(
        "ckptwin-campaign-{tag}-{}.jsonl",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&p);
    p
}

/// A grid small enough for unit tests but structurally like the paper's.
fn small_grid() -> Grid {
    Grid {
        procs: vec![1 << 16, 1 << 17],
        cp_ratios: vec![1.0],
        fault_laws: vec![Law::Exponential, Law::Weibull { shape: 0.7 }],
        uniform_false_preds: false,
        predictors: vec![predictors::get("a").unwrap()],
        windows: vec![600.0],
        strategies: vec![
            registry::get("RFO").unwrap(),
            registry::get("NoCkptI").unwrap(),
        ],
        scale: 0.02,
        platform_shards: vec![1],
    }
}

#[test]
fn grid_expansion_count_and_determinism() {
    let g = Grid::paper();
    let cells = g.expand();
    // 4 N × 2 C_p × 3 laws × 2 predictors × 5 windows × 5 strategies.
    assert_eq!(cells.len(), 1200);
    assert_eq!(cells.len(), g.len());
    let again = g.expand();
    for (a, b) in cells.iter().zip(&again) {
        assert_eq!(a.key(), b.key());
        assert_eq!(a.hash, b.hash);
        assert_eq!(a.instance_seed(3), b.instance_seed(3));
    }
    // Deterministic order: outermost axis is the fault law.
    assert_eq!(cells[0].fault_law, Law::Exponential);
    assert_eq!(cells[0].strategy, registry::get("Daly").unwrap());
    assert_eq!(cells[1].strategy, registry::get("RFO").unwrap());
}

/// The registry port must not move a single store key: these literal
/// strings (and their FNV-1a hashes) are what pre-registry stores were
/// keyed on, so pinning them proves existing JSONL stores still resume.
#[test]
fn store_keys_stable_across_registry_port() {
    let cell = |strat: &str| {
        ckptwin::campaign::Cell::new(
            1 << 16,
            1.0,
            Law::Exponential,
            Law::Exponential,
            ckptwin::PredictorSpec::paper_a(600.0),
            StrategyId::parse(strat).unwrap(),
            1.0,
        )
    };
    for name in ["Daly", "Young", "RFO", "Instant", "NoCkptI", "WithCkptI"] {
        let c = cell(name);
        let expected = format!(
            "procs=65536;cp=1;law=exponential;fp=exponential;scale=1;\
             p=0.82;r=0.85;I=600;strat={name}"
        );
        assert_eq!(c.key(), expected);
        assert_eq!(c.hash, fnv1a64(expected.as_bytes()));
    }
    // One fully pinned hash: any change to the key grammar or the hash
    // function breaks resumability even if key() and hash stay mutually
    // consistent.
    let daly = cell("Daly");
    assert_eq!(
        daly.hash,
        fnv1a64(
            b"procs=65536;cp=1;law=exponential;fp=exponential;scale=1;\
              p=0.82;r=0.85;I=600;strat=Daly"
        )
    );
}

/// The predictor-registry port must not move paper-predictor keys either:
/// the `pm=<model>` key component appears ONLY for non-paper placement
/// models, so every pre-existing store (paper predictors by construction)
/// still resumes; non-paper cells get their own stable, pinned grammar.
#[test]
fn predictor_model_keys_extend_without_moving_legacy_ones() {
    let cell = |spec: ckptwin::PredictorSpec| {
        ckptwin::campaign::Cell::new(
            1 << 16,
            1.0,
            Law::Exponential,
            Law::Exponential,
            spec,
            StrategyId::parse("NoCkptI").unwrap(),
            1.0,
        )
    };
    // Legacy grammar, byte-identical (no pm component anywhere).
    let paper = cell(ckptwin::PredictorSpec::paper_a(600.0));
    assert_eq!(
        paper.key(),
        "procs=65536;cp=1;law=exponential;fp=exponential;scale=1;\
         p=0.82;r=0.85;I=600;strat=NoCkptI"
    );
    // A registered non-paper model appends its canonical label before the
    // strategy component.
    let biased = cell(
        predictors::PredictorId::parse("biased(beta=2)")
            .unwrap()
            .spec(600.0),
    );
    let expected = "procs=65536;cp=1;law=exponential;fp=exponential;scale=1;\
                    p=0.82;r=0.85;I=600;pm=biased(beta=2);strat=NoCkptI";
    assert_eq!(biased.key(), expected);
    assert_eq!(biased.hash, fnv1a64(expected.as_bytes()));
    // Distinct models are distinct store rows at one scenario point…
    let jitter = cell(
        predictors::PredictorId::parse("jitter(sigma=120;r=0.85;p=0.82)")
            .unwrap()
            .spec(600.0),
    );
    assert_ne!(biased.hash, jitter.hash);
    // …but all predictor variants share the fault-environment seeds
    // (paired comparisons across the predictor axis).
    assert_eq!(paper.trace_hash, biased.trace_hash);
    assert_eq!(paper.instance_seed(9), jitter.instance_seed(9));
}

/// A store written before the registry port (simulated by writing records
/// under the pinned legacy keys) is recognized by a post-port resume: every
/// cell is skipped, nothing is recomputed.
#[test]
fn legacy_store_resumes_under_registry() {
    let path = tmp("legacy");
    let g = small_grid();
    let cells = g.expand();
    let opt = CampaignOptions { instances: 2, block: 1, threads: 1 };

    // Write the store with today's code...
    let mut store = Store::create(&path).unwrap();
    campaign::run_cells(&cells, &opt, Some(&mut store)).unwrap();
    drop(store);
    // ...and verify the on-disk keys are exactly the legacy strings.
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("strat=RFO"), "{text}");
    assert!(text.contains("strat=NoCkptI"));

    let mut store = Store::open(&path).unwrap();
    let (done, skipped) =
        campaign::run_cells(&cells, &opt, Some(&mut store)).unwrap();
    assert!(done.is_empty());
    assert_eq!(skipped, cells.len());
}

#[test]
fn scenario_hash_is_stable_and_parameter_sensitive() {
    // The hash is FNV-1a of the canonical key — pinned to the published
    // FNV-1a test vectors so an accidental algorithm change is caught even
    // though cell hashes themselves are computed, not hardcoded.
    assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
    assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);

    let cells = small_grid().expand();
    for c in &cells {
        assert_eq!(c.hash, fnv1a64(c.key().as_bytes()));
    }
    // Any single-axis change moves the hash.
    let mut seen: Vec<u64> = cells.iter().map(|c| c.hash).collect();
    seen.sort_unstable();
    seen.dedup();
    assert_eq!(seen.len(), cells.len());
}

#[test]
fn work_stealing_matches_single_thread() {
    // Property: the per-cell aggregates are BIT-identical between
    // single-thread and multi-thread execution, for several block sizes.
    let g = small_grid();
    for block in [1, 3, 0] {
        let opt1 = CampaignOptions { instances: 6, block, threads: 1 };
        let opt8 = CampaignOptions { instances: 6, block, threads: 8 };
        let a = campaign::evaluate_grid(&g, &opt1);
        let b = campaign::evaluate_grid(&g, &opt8);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.cell.hash, y.cell.hash);
            assert_eq!(x.waste, y.waste, "cell {}", x.cell.key());
            assert_eq!(x.makespan, y.makespan);
            assert_eq!(x.tr, y.tr);
        }
    }
}

#[test]
fn resume_skips_completed_cells() {
    let path = tmp("skip");
    let g = small_grid();
    let cells = g.expand();
    let opt = CampaignOptions { instances: 3, block: 2, threads: 2 };

    // Fresh run computes everything.
    let mut store = Store::create(&path).unwrap();
    let (done, skipped) = campaign::run_cells(&cells, &opt, Some(&mut store)).unwrap();
    assert_eq!(done.len(), cells.len());
    assert_eq!(skipped, 0);
    assert_eq!(store.len(), cells.len());
    drop(store);

    // Resume over the complete store computes nothing.
    let mut store = Store::open(&path).unwrap();
    let (done, skipped) = campaign::run_cells(&cells, &opt, Some(&mut store)).unwrap();
    assert!(done.is_empty());
    assert_eq!(skipped, cells.len());
}

#[test]
fn resume_recomputes_underpowered_cells() {
    // A store built with fewer instances than requested is not "complete":
    // resume recomputes those cells and the new records supersede the old.
    let path = tmp("upgrade");
    let mut g = small_grid();
    g.procs = vec![1 << 16];
    let cells = g.expand();

    let mut store = Store::create(&path).unwrap();
    let quick = CampaignOptions { instances: 2, block: 1, threads: 2 };
    campaign::run_cells(&cells, &quick, Some(&mut store)).unwrap();
    drop(store);

    let mut store = Store::open(&path).unwrap();
    let precise = CampaignOptions { instances: 5, block: 2, threads: 2 };
    let (done, skipped) = campaign::run_cells(&cells, &precise, Some(&mut store)).unwrap();
    assert_eq!(done.len(), cells.len());
    assert_eq!(skipped, 0);
    drop(store);

    // Reload: last-wins, every record upgraded to 5 instances...
    let store = Store::open(&path).unwrap();
    assert_eq!(store.len(), cells.len());
    for c in &cells {
        assert_eq!(store.get(c.hash).unwrap().instances, 5);
    }
    drop(store);
    // ...and a downgrade request (2 ≤ 5) skips everything.
    let mut store = Store::open(&path).unwrap();
    let (done, skipped) = campaign::run_cells(&cells, &quick, Some(&mut store)).unwrap();
    assert!(done.is_empty());
    assert_eq!(skipped, cells.len());
}

/// Acceptance: a ≥200-cell grid runs end-to-end, writes per-cell JSONL
/// results with stable scenario hashes, and resuming an interrupted store
/// recomputes only the missing cells — with values identical to an
/// uninterrupted run.
#[test]
fn interrupted_campaign_resumes_exactly() {
    // 2^16..2^19 × 2 C_p × {exp, weibull0.7, lognormal1.2} × {A, B} ×
    // 3 windows × 1 strategy = 288 cells (scaled-down job for test speed).
    let grid = Grid {
        procs: vec![1 << 16, 1 << 17, 1 << 18, 1 << 19],
        cp_ratios: vec![1.0, 0.1],
        fault_laws: vec![
            Law::Exponential,
            Law::Weibull { shape: 0.7 },
            Law::LogNormal { sigma: 1.2 },
        ],
        uniform_false_preds: false,
        predictors: predictors::paper_pair(),
        windows: vec![300.0, 600.0, 900.0],
        strategies: vec![registry::get("NoCkptI").unwrap()],
        scale: 0.01,
        platform_shards: vec![1],
    };
    let cells = grid.expand();
    assert!(cells.len() >= 200, "{} cells", cells.len());
    let opt = CampaignOptions { instances: 2, block: 1, threads: 0 };

    // Reference: one uninterrupted run.
    let ref_path = tmp("ref");
    let mut ref_store = Store::create(&ref_path).unwrap();
    let (reference, _) = campaign::run_cells(&cells, &opt, Some(&mut ref_store)).unwrap();
    assert_eq!(reference.len(), cells.len());
    assert_eq!(ref_store.len(), cells.len());
    drop(ref_store);

    // Every cell landed in the JSONL with its stable hash.
    let ref_store = Store::open(&ref_path).unwrap();
    for c in &cells {
        let rec = ref_store.get(c.hash).unwrap_or_else(|| {
            panic!("cell {} missing from store", c.key())
        });
        assert_eq!(rec.key, c.key());
        assert_eq!(rec.instances, 2);
        assert!(rec.waste_mean.is_finite() && rec.waste_mean > 0.0);
    }

    // "Interrupt": keep only the first 100 JSONL lines, plus a torn
    // partial line as a crash would leave behind.
    let int_path = tmp("int");
    let text = std::fs::read_to_string(&ref_path).unwrap();
    let mut head: String = text.lines().take(100).collect::<Vec<_>>().join("\n");
    head.push('\n');
    head.push_str("{\"hash\":\"00000000");
    std::fs::write(&int_path, head).unwrap();

    // Resume: exactly the missing cells are recomputed.
    let mut store = Store::open(&int_path).unwrap();
    assert_eq!(store.len(), 100);
    assert_eq!(store.skipped_lines, 1);
    let (resumed, skipped) = campaign::run_cells(&cells, &opt, Some(&mut store)).unwrap();
    assert_eq!(skipped, 100);
    assert_eq!(resumed.len(), cells.len() - 100);
    assert_eq!(store.len(), cells.len());
    drop(store);

    // The resumed store is record-for-record identical to the reference.
    let resumed_store = Store::open(&int_path).unwrap();
    for c in &cells {
        assert_eq!(
            resumed_store.get(c.hash).unwrap(),
            ref_store.get(c.hash).unwrap(),
            "cell {} differs after resume",
            c.key()
        );
    }
}

/// Regression: grid overrides used to silently ignore unknown keys — a
/// typo like `--strategis daly` ran the full default grid without
/// complaint.  Unknown keys now error and name the nearest known key.
#[test]
fn unknown_override_keys_error_with_nearest_match() {
    use ckptwin::campaign::overrides;

    let mut g = small_grid();
    let before = g.expand().len();
    let err = overrides::apply_override(&mut g, "strategis", "daly").unwrap_err();
    assert!(err.contains("unknown grid axis 'strategis'"), "{err}");
    assert!(err.contains("did you mean 'strategies'"), "{err}");
    // The failed override must not have touched the grid.
    assert_eq!(g.expand().len(), before);

    // The CLI key check rejects typo'd option names the same way.
    let err = overrides::check_keys(["procs", "strategis"], &["out"]).unwrap_err();
    assert!(err.contains("--strategis"), "{err}");
    assert!(err.contains("did you mean '--strategies'"), "{err}");
    assert!(overrides::check_keys(["procs", "out", "uniform-fp"], &["out"]).is_ok());

    // Bad registry ids inside a list get a nearest-id suggestion too.
    let err = overrides::apply_override(&mut g, "strategies", "dailly").unwrap_err();
    assert!(err.to_ascii_lowercase().contains("did you mean 'daly'"), "{err}");
}
