//! Batched-model equivalence gates (PR 10).
//!
//! The `model::batch` evaluator is only allowed to be a *schedule* change:
//! every cell it emits must be bit-for-bit the scalar result — value AND
//! inapplicability reason — across the full strategy/predictor registries,
//! all three laws, and adversarial grids.  On top of that sits the
//! BestPeriod equivalence: batched model seeding must race to the exact
//! same winner (and elimination trace) as scalar seeding, and stay within
//! the paired tolerance of the exhaustive sweep.

use ckptwin::config::{FaultModel, Platform, PredictorSpec, Scenario};
use ckptwin::model::batch::{BatchEvaluator, STRATEGIES};
use ckptwin::model::optimal;
use ckptwin::model::waste::{waste_checked, waste_clipped};
use ckptwin::sim::distribution::Law;
use ckptwin::sim::engine::simulate_q;
use ckptwin::sim::trace::TraceCache;
use ckptwin::strategy::best_period::{
    search_exhaustive, search_logged, ModelSide, SearchConfig,
};
use ckptwin::strategy::{registry, Policy, PolicyKind};
use ckptwin::validate::domain;
use ckptwin::validate::TolerancePolicy;

const LAWS: [Law; 3] = [
    Law::Exponential,
    Law::Weibull { shape: 0.7 },
    Law::LogNormal { sigma: 1.2 },
];

/// Adversarial period grids: empty, single-point, denormal-adjacent,
/// descending, duplicated T_R — plus a realistic geometric sweep.
fn adversarial_grids() -> Vec<Vec<f64>> {
    let geo: Vec<f64> = (0..33)
        .map(|k| 650.0 * (200_000.0f64 / 650.0).powf(k as f64 / 32.0))
        .collect();
    vec![
        vec![],
        vec![700.0],
        vec![f64::MIN_POSITIVE, 5e-324, 650.0, 1e-300, 4000.0],
        vec![50_000.0, 8000.0, 700.0, 100.0],
        vec![700.0, 700.0, 8000.0, 8000.0, 700.0],
        geo,
    ]
}

/// One cell's bitwise identity: value bits AND reason.
#[track_caller]
fn assert_cell_identical(
    got: ckptwin::model::waste::Applicability,
    want: ckptwin::model::waste::Applicability,
    ctx: &str,
) {
    assert_eq!(
        got.value().map(f64::to_bits),
        want.value().map(f64::to_bits),
        "value bits diverged: {ctx} (batch {got:?} vs scalar {want:?})"
    );
    assert_eq!(
        got.reason(),
        want.reason(),
        "reason diverged: {ctx} (batch {got:?} vs scalar {want:?})"
    );
}

/// Satellite 3, main property: `eval_row` ≡ scalar `waste_checked`
/// bit-for-bit over every registry default × law × adversarial grid.
#[test]
fn batch_rows_match_scalar_checked_across_registries() {
    let grids = adversarial_grids();
    let mut ev = BatchEvaluator::new();
    let mut covered = std::collections::BTreeSet::new();
    for law in LAWS {
        for pid in ckptwin::predictor::registry::all_defaults() {
            let mut sc = Scenario::paper(1 << 16, 1.0, pid.spec(900.0), law, law);
            sc.job_size *= 0.05;
            let tp = registry::default_tp(&sc);
            for sid in registry::all_defaults() {
                let Some(gs) = sid.kind().grid_strategy() else {
                    continue;
                };
                covered.insert(gs as usize);
                for grid in &grids {
                    let mut row = Vec::new();
                    ev.eval_row(&sc, gs, tp, grid, &mut row);
                    assert_eq!(row.len(), grid.len());
                    for (i, &tr) in grid.iter().enumerate() {
                        assert_cell_identical(
                            row[i],
                            waste_checked(&sc, gs, tr, tp),
                            &format!(
                                "{sid} / {pid} / {} / tr={tr}",
                                law.label()
                            ),
                        );
                    }
                }
            }
        }
    }
    // Every closed-form column must have been exercised.
    assert_eq!(covered.len(), STRATEGIES.len());
}

/// Row-guard scenarios (μ ≤ D+R, p = 0, T_P outside the window) classify
/// identically to the scalar guards — the hoisting must not reorder the
/// observable reason.
#[test]
fn batch_row_guards_match_scalar_reasons() {
    let base = Scenario {
        platform: Platform { mu: 30_000.0, c: 600.0, cp: 600.0, d: 60.0, r: 600.0 },
        predictor: PredictorSpec::paper(0.85, 0.82, 600.0),
        fault_law: Law::Exponential,
        false_pred_law: Law::Exponential,
        fault_model: FaultModel::PlatformRenewal,
        job_size: 1e7,
    };
    let mut dead_mu = base;
    dead_mu.platform.mu = 500.0; // μ ≤ D + R
    let mut zero_p = base;
    zero_p.predictor = PredictorSpec::paper(0.85, 0.0, 600.0);
    let grid = [100.0, 700.0, 5000.0, 60_000.0];
    let mut ev = BatchEvaluator::new();
    for sc in [&base, &dead_mu, &zero_p] {
        // tp = 50.0 additionally violates the WithCkpt window guard.
        for tp in [registry::default_tp(sc), 50.0] {
            for strat in STRATEGIES {
                let mut row = Vec::new();
                ev.eval_row(sc, strat, tp, &grid, &mut row);
                for (i, &tr) in grid.iter().enumerate() {
                    assert_cell_identical(
                        row[i],
                        waste_checked(sc, strat, tr, tp),
                        &format!("{strat:?} tp={tp} tr={tr}"),
                    );
                }
            }
        }
    }
}

/// Kernel-semantics rows: `clipped_row` ≡ scalar `waste_clipped` bitwise
/// over the adversarial grids (the f64 side of the PJRT cross-check).
#[test]
fn batch_clipped_rows_match_scalar_clipped() {
    let mut ev = BatchEvaluator::new();
    for law in [Law::Exponential, Law::Weibull { shape: 0.7 }] {
        for pred in [PredictorSpec::paper_a(300.0), PredictorSpec::paper_b(1200.0)] {
            let sc = Scenario::paper(1 << 18, 0.1, pred, law, law);
            for grid in &adversarial_grids() {
                for strat in STRATEGIES {
                    let mut row = Vec::new();
                    ev.clipped_row(&sc, strat, grid, &mut row);
                    assert_eq!(row.len(), grid.len());
                    for (i, &tr) in grid.iter().enumerate() {
                        assert_eq!(
                            row[i].to_bits(),
                            waste_clipped(&sc, strat, tr).to_bits(),
                            "{strat:?} tr={tr}"
                        );
                    }
                }
            }
        }
    }
}

/// `classify_batch` ≡ scalar `classify` element-wise (value bits and
/// reason) across the registry defaults — the validate pre-pass contract.
#[test]
fn classify_batch_matches_scalar_across_registries() {
    let pol = TolerancePolicy::default();
    let trs: Vec<f64> = vec![100.0, 650.0, 700.0, 8000.0, 8000.0, 40_000.0, 150_000.0];
    let mut ev = BatchEvaluator::new();
    for law in LAWS {
        for pid in ckptwin::predictor::registry::all_defaults() {
            let mut sc = Scenario::paper(1 << 16, 1.0, pid.spec(900.0), law, law);
            sc.job_size *= 0.05;
            let tp = registry::default_tp(&sc);
            for sid in registry::all_defaults() {
                let kind = sid.kind();
                let batch = domain::classify_batch(&sc, kind, &trs, tp, &pol, &mut ev);
                assert_eq!(batch.len(), trs.len());
                for (i, &tr) in trs.iter().enumerate() {
                    let scalar = domain::classify(&sc, kind, tr, tp, &pol);
                    match (batch[i], scalar) {
                        (Ok(b), Ok(s)) => assert_eq!(
                            b.to_bits(),
                            s.to_bits(),
                            "{sid} / {pid} / {} / tr={tr}",
                            law.label()
                        ),
                        (b, s) => assert_eq!(
                            b, s,
                            "{sid} / {pid} / {} / tr={tr}",
                            law.label()
                        ),
                    }
                }
            }
        }
    }
}

// ---- BestPeriod equivalence (satellite 4) ------------------------------

const KINDS: [PolicyKind; 4] = [
    PolicyKind::IgnorePredictions,
    PolicyKind::Instant,
    PolicyKind::NoCkpt,
    PolicyKind::WithCkpt,
];

/// The fast-path golden scenario: scaled-down paper run under predictor B
/// (both false predictions and unpredicted faults present).
fn golden(law: Law) -> Scenario {
    let mut sc =
        Scenario::paper(1 << 16, 1.0, PredictorSpec::paper_b(900.0), law, law);
    sc.job_size *= 0.05;
    sc
}

/// Batched and scalar model seeding produce the same candidate ranking,
/// hence the same winner AND the same elimination trace, on the golden
/// scenarios — all four policy kinds, all three laws.
#[test]
fn best_period_batched_equals_scalar_seeding() {
    for law in LAWS {
        let sc = golden(law);
        let tp = optimal::tp_extr(&sc).max(sc.platform.cp * 1.1);
        let seeds: Vec<u64> = (0..6).collect();
        for kind in KINDS {
            let run = |side: ModelSide| {
                let mut caches: Vec<TraceCache> =
                    seeds.iter().map(|&s| TraceCache::new(&sc, s)).collect();
                search_logged(
                    &sc,
                    kind,
                    tp,
                    &seeds,
                    &SearchConfig::adaptive(16, 6).with_model(side),
                    &mut caches,
                )
            };
            let (bp_b, log_b) = run(ModelSide::Batched);
            let (bp_s, log_s) = run(ModelSide::Scalar);
            let ctx = format!("{kind:?} / {}", law.label());
            assert_eq!(bp_b.tr.to_bits(), bp_s.tr.to_bits(), "winner: {ctx}");
            assert_eq!(bp_b.waste.to_bits(), bp_s.waste.to_bits(), "waste: {ctx}");
            assert_eq!(bp_b.evals, bp_s.evals, "evals: {ctx}");
            assert_eq!(log_b, log_s, "elimination trace: {ctx}");
        }
    }
}

/// Paired tolerance vs the exhaustive sweep: the batch-seeded adaptive
/// winner, re-scored on the full seed set, stays within the configured
/// tolerance of the exhaustive winner (model pruning must never drop the
/// empirical optimum).
#[test]
fn best_period_batched_within_tolerance_of_exhaustive() {
    for law in [Law::Exponential, Law::Weibull { shape: 0.7 }] {
        let sc = golden(law);
        let tp = optimal::tp_extr(&sc).max(sc.platform.cp * 1.1);
        let seeds: Vec<u64> = (0..6).collect();
        let tol = SearchConfig::adaptive(16, 6).tolerance;
        let mean_waste = |kind: PolicyKind, tr: f64| {
            let pol = Policy { kind, tr, tp };
            seeds
                .iter()
                .map(|&s| simulate_q(&sc, &pol, 1.0, s).waste())
                .sum::<f64>()
                / seeds.len() as f64
        };
        for kind in [PolicyKind::IgnorePredictions, PolicyKind::WithCkpt] {
            let exact = search_exhaustive(&sc, kind, tp, &seeds, 16, 6);
            let mut caches: Vec<TraceCache> =
                seeds.iter().map(|&s| TraceCache::new(&sc, s)).collect();
            let (fast, _) = search_logged(
                &sc,
                kind,
                tp,
                &seeds,
                &SearchConfig::adaptive(16, 6),
                &mut caches,
            );
            let w_fast = mean_waste(kind, fast.tr);
            assert!(
                w_fast <= exact.waste + 2.0 * tol,
                "{kind:?} / {}: batched adaptive {w_fast} (tr {}) vs \
                 exhaustive {} (tr {})",
                law.label(),
                fast.tr,
                exact.waste,
                exact.tr
            );
        }
    }
}

/// Placeholder-free sanity on the inapplicable path: a kind without a grid
/// column never lets the model drop candidates (the search must behave as
/// ModelSide::Off there), pinned end-to-end through search_logged.
#[test]
fn best_period_no_closed_form_kind_races_unseeded() {
    let sc = golden(Law::Exponential);
    let tp = optimal::tp_extr(&sc).max(sc.platform.cp * 1.1);
    let seeds: Vec<u64> = (0..4).collect();
    let kind = PolicyKind::QTrust { q: 0.5 };
    let run = |side: ModelSide| {
        let mut caches: Vec<TraceCache> =
            seeds.iter().map(|&s| TraceCache::new(&sc, s)).collect();
        search_logged(
            &sc,
            kind,
            tp,
            &seeds,
            &SearchConfig::adaptive(12, 4).with_model(side),
            &mut caches,
        )
    };
    let (bp_batch, log_batch) = run(ModelSide::Batched);
    let (bp_off, log_off) = run(ModelSide::Off);
    assert_eq!(bp_batch.tr.to_bits(), bp_off.tr.to_bits());
    assert_eq!(bp_batch.evals, bp_off.evals);
    assert_eq!(log_batch, log_off);
}
